"""TppGraph — declarative IR for TPP-chain fusion (paper §IV-A, Listing 6).

A graph is a tuple of **contraction roots** (GEMMs over flat 2D operands, the
BRGEMM/GEMM TPP — roots may share an ``lhs`` operand) plus an **epilogue DAG**
of unary/binary/normalization TPPs applied to the contraction results while
they are still VMEM-resident.  This is exactly the paper's fused-layer shape:
"chains of TPPs" inside one PARLOOPER nest, where every operator after the
contraction works at small 2D-block granularity "to maximize the out-of-cache
reuse of tensors among subsequent operators".  Multi-root graphs cover the
paper's multi-GEMM fused blocks: the gated MLP (``silu(x@wg) * (x@wu)``) and
the fused QKV projection (one lhs, three rhs, stacked output).

The IR is deliberately tiny:

  * ``OperandSpec`` — a named graph input with a *kind* that fixes its shape
    role relative to the contraction ``C[M,N] = A[M,K] @ B[K,N]``:
      - ``lhs``    (M, K)   contraction A
      - ``rhs``    (K, N)   contraction B
      - ``tile``   (M, N)   elementwise epilogue operand (residual, …)
      - ``mask``   (M, N)   boolean epilogue operand (legacy dropout mask)
      - ``rowvec`` (N,)     row-broadcast vector (bias, gamma, beta)
      - ``scalar`` ()       traced scalar (the ``dropout_rng`` PRNG seed)
      - ``crhs``   (N, N2)  a *chained* contraction's rhs (see below)
    ``lhs``/``rhs`` operands may set ``trans=True``: the array is *stored*
    transposed relative to its contraction role (a trans lhs has array shape
    (K, M), a trans rhs (N, K)) and the lowering reads it with a transposed
    tile layout — no materialized transpose.  This is what lets backward
    graphs (``fusion.autodiff``) reuse the forward operands in place:
    dLHS = dY @ rhsᵀ and dRHS = lhsᵀ @ dY consume the forward rhs/lhs arrays
    through transposed loads.
  * ``ContractionRoot`` — one named GEMM ``root = lhs @ rhs``; the root name
    is a value visible to every epilogue node.  All roots of a graph share
    the problem shape (M, K, N) — that is what lets one loop nest carry them
    and load a shared A tile once per (M, K) visit.
  * ``Node`` — one epilogue TPP application; inputs name a root's accumulator
    (``"acc"`` stays as an alias when there is exactly one root), earlier
    nodes, or operands.
  * ``TppGraph`` — operands + roots + topologically ordered nodes +
    ``outputs`` (value names).  With one output the graph returns (M, N);
    with R > 1 outputs the values are stacked on a leading axis → (R, M, N)
    (the fused-QKV shape).  At most one node may *reduce* (layernorm /
    rmsnorm / softmax over the N axis); it must be the last node and the
    graph must be single-output — the lowering handles it with the row-panel
    statistics trick.

A **chained root** (``ContractionRoot(..., chained=True)``) consumes the
reduced epilogue of the base roots as its lhs and a ``crhs`` operand
(stored (N, N2)) as its rhs: ``O = reduce(epilogue(S)) @ V``.  The reducer
must be an *online* one (``ONLINE_REDUCERS`` — ``softmax_online`` carries a
streaming (running max, running sum) recurrence), so the reduced (M, N)
panel is never materialized: the Pallas lowering streams each tile into an
(M, N2) chain accumulator rescaled through a statistics strip.  This is
flash attention as IR — structural rules are ``TPP212``/``TPP213``, the
full story is in ``docs/fusion_attention.md``.

Epilogue TPPs are drawn from a fixed registry (``EPILOGUE_OPS``) whose
``apply`` functions operate on fp32 values — the same functions run in the XLA
reference path (on full arrays) and inside the Pallas kernel body (on VMEM
tiles), which is what makes the two lowerings agree bit-for-bit up to
contraction blocking order.

``simplify_graph`` is the graph-level cleanup pass run by ``fusion.compile``:
``identity`` nodes and rate-0 ``dropout``/``dropout_rng`` nodes forward
their value input, and operands no longer referenced by any node/root/output
are dropped (so a rate-0 dropout's keep-mask — or a rate-0 ``dropout_rng``'s
seed — never becomes a mapped kernel operand).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import tpp
from repro.core.loops import LegalityError

__all__ = [
    "FusionLegalityError", "OperandSpec", "ContractionRoot", "Node",
    "TppGraph", "EpilogueOp", "EPILOGUE_OPS", "ONLINE_REDUCERS",
    "register_epilogue", "simplify_graph",
]

OPERAND_KINDS = ("lhs", "rhs", "crhs", "tile", "mask", "rowvec", "scalar")


class FusionLegalityError(LegalityError):
    """Raised when a TppGraph is malformed or cannot be lowered onto the
    requested loop nest (e.g. a normalizing epilogue whose reduction axis
    conflicts with the nest's innermost band).  Carries a stable ``.code``
    (``TPP2xx`` — see ``repro.analysis.diagnostics.CATALOG``) so tests pin
    the diagnostic, not the message string."""


@dataclasses.dataclass(frozen=True)
class OperandSpec:
    name: str
    kind: str
    trans: bool = False     # lhs/rhs only: array stored transposed

    def __post_init__(self):
        if self.kind not in OPERAND_KINDS:
            raise FusionLegalityError(
                f"operand {self.name!r}: unknown kind {self.kind!r}; "
                f"expected one of {OPERAND_KINDS}", code="TPP210")
        if self.trans and self.kind not in ("lhs", "rhs"):
            raise FusionLegalityError(
                f"operand {self.name!r}: trans=True only applies to "
                f"contraction operands (lhs/rhs), not {self.kind!r}",
                code="TPP210")


@dataclasses.dataclass(frozen=True)
class ContractionRoot:
    """One GEMM root ``name = lhs @ rhs``: ``lhs``/``rhs`` are operand names
    of the matching kinds, ``name`` is the accumulator value visible to the
    epilogue DAG.  Roots may share an ``lhs`` operand (fused QKV / gated MLP
    read the activation once).

    A **chained** root (``chained=True``) consumes a *computed value* instead
    of an lhs operand: its ``lhs`` names the graph's reducing node (which
    must be an online reducer — ``softmax_online``), and its ``rhs`` names a
    ``crhs`` operand of array shape (N, N2) contracted over the base roots'
    N axis.  The lowering never materializes the reduced (M, N) panel:
    partial products accumulate into an (M, N2) chain accumulator, rescaled
    by the streaming (running max, running sum) statistics strip as new N
    tiles arrive — online softmax as IR, i.e. flash attention derived."""

    name: str
    lhs: str
    rhs: str
    chained: bool = False


@dataclasses.dataclass(frozen=True)
class Node:
    """One epilogue TPP application.  ``inputs`` are value names: ``"acc"``,
    an earlier node's name, or an operand name.  ``attrs`` are static op
    parameters (e.g. dropout rate, norm eps) as a sorted kv tuple."""

    name: str
    op: str
    inputs: tuple[str, ...]
    attrs: tuple[tuple[str, Any], ...] = ()

    def attr_dict(self) -> dict:
        return dict(self.attrs)


# ---------------------------------------------------------------------------
# Epilogue op registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EpilogueOp:
    """A registered epilogue TPP.

    ``value_arity``     — how many leading inputs are *values* (acc / node
                          outputs / ``tile``/``mask`` operands);
    ``operand_kinds``   — kinds of the trailing inputs, which must be graph
                          operands (e.g. ``("rowvec",)`` for bias_add);
    ``reduces``         — ``None`` for pointwise ops, ``"n"`` when the op
                          reduces over the feature (N) axis and therefore
                          needs the full row resident;
    ``apply``           — fp32 tile semantics, shared by every lowering path;
    ``flops_per_elem``  — rough VPU flop count per output element, consumed
                          by the perf model's fused-epilogue term;
    ``grad``            — reverse-mode rule consumed by ``fusion.autodiff``:
                          ``None`` (non-differentiable — deriving a VJP
                          through the op raises), the *name* of a registered
                          derivative op (see the arity contract below), or a
                          callable ``rule(sweep, node, dv) -> {input: value}``
                          that emits cotangent nodes through the sweep;
    ``stats_input``     — for reducing ops, the index of the value input
                          whose per-row (sum, sum-of-squares) strip the
                          Pallas lowering accumulates tile-by-tile (the
                          row-panel statistics trick); ``None`` → the op is
                          applied to the finished full-row panel directly;
    ``wants_offsets``   — the op's ``apply`` takes an ``_offsets=(row0,
                          col0)`` kwarg: the global element coordinates of
                          the tile it is applied to (the in-kernel PRNG ops
                          key their counter-based draw on them).  Lowerings
                          inject the current tile's offsets; full-array call
                          sites rely on the ``(0, 0)`` default.

    A *named* grad op must agree with its forward op: identical
    ``operand_kinds``, and a ``value_arity`` of either the forward arity (the
    cotangent dv substitutes for the primal value input — e.g. dropout, whose
    grad is the same masked scaling applied to dv) or forward arity + 1 (dv
    is prepended and the primal value inputs are re-supplied — e.g.
    ``relu_grad(dv, x)``).  ``register_epilogue`` enforces this as soon as
    both sides are registered.
    """

    name: str
    value_arity: int
    operand_kinds: tuple[str, ...]
    apply: Callable
    reduces: Optional[str] = None
    flops_per_elem: float = 1.0
    grad: Any = None
    stats_input: Optional[int] = None
    wants_offsets: bool = False


EPILOGUE_OPS: dict[str, EpilogueOp] = {}


def _check_grad_arity(fwd: EpilogueOp, gop: EpilogueOp):
    """A named grad op must take the same trailing operands and either
    substitute dv for the primal (same value arity) or prepend it (+1)."""
    ok_arity = gop.value_arity in (fwd.value_arity, fwd.value_arity + 1)
    if not ok_arity or gop.operand_kinds != fwd.operand_kinds:
        raise FusionLegalityError(
            f"epilogue op {fwd.name!r}: grad op {gop.name!r} disagrees with "
            f"its forward op — expected value_arity {fwd.value_arity} "
            f"(dv substitution) or {fwd.value_arity + 1} (dv prepended) with "
            f"operand_kinds {fwd.operand_kinds}, got value_arity "
            f"{gop.value_arity} / operand_kinds {gop.operand_kinds}",
            code="TPP204")


def register_epilogue(op: EpilogueOp, *, override: bool = False):
    """Register ``op`` under its name.  Re-registering an existing name is an
    error unless ``override=True`` — a silent overwrite would retroactively
    change the semantics of every graph already built against the name (and
    of every schedule the tune cache persisted for it)."""
    if op.name in EPILOGUE_OPS and not override:
        raise FusionLegalityError(
            f"epilogue op {op.name!r} is already registered; pass "
            "override=True to replace it deliberately")
    # all checks run BEFORE the registry is touched — a failed registration
    # must not leave a half-registered op behind
    if isinstance(op.grad, str) and op.grad in EPILOGUE_OPS:
        _check_grad_arity(op, EPILOGUE_OPS[op.grad])
    # ops may be registered before their grad op exists — check the reverse
    # direction too, so the pair is validated whichever side lands second
    for other in EPILOGUE_OPS.values():
        if isinstance(other.grad, str) and other.grad == op.name:
            _check_grad_arity(other, op)
    EPILOGUE_OPS[op.name] = op
    return op


def _f32(x):
    return x.astype(jnp.float32)


def _dropout_apply(v, mask, *, rate: float = 0.0):
    # the 1/(1-rate) rescale runs in fp32 regardless of the value dtype — in
    # bf16 both the scale constant and the product would round, drifting off
    # the fp32 accumulator band the rest of the epilogue computes in
    if rate <= 0.0:
        return v
    return jnp.where(mask, v.astype(jnp.float32)
                     * jnp.float32(1.0 / (1.0 - rate)), jnp.float32(0.0))


def _dropout_rng_apply(v, seed, *, rate: float = 0.0, salt: int = 0,
                       _offsets=(0, 0), _impl: str = "counter"):
    """In-kernel counter-based dropout: keep bits are regenerated from
    ``(seed, salt, element coordinates)`` — no (M, N) mask operand.  The
    same function runs on full arrays (XLA reference, offsets (0, 0)) and on
    VMEM tiles (the Pallas lowering injects the tile's global offsets), so
    every backend — and every schedule — draws identical bits.  Threshold
    compare is exact integer; the survivor rescale runs in fp32."""
    from repro.fusion import rng
    if rate <= 0.0:
        return v
    seed = jnp.asarray(seed).reshape(()).astype(jnp.uint32)
    bits_fn = rng.hw_tile_bits if _impl == "hw" else rng.tile_bits
    bits = bits_fn(seed, jnp.uint32(salt), jnp.shape(v), offsets=_offsets)
    keep = bits < jnp.uint32(rng.keep_threshold(rate))
    return jnp.where(keep, v.astype(jnp.float32)
                     * jnp.float32(1.0 / (1.0 - rate)), jnp.float32(0.0))


def _layernorm_apply(v, gamma, beta, *, eps: float = 1e-5):
    mu = jnp.mean(v, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(v - mu), axis=-1, keepdims=True)
    y = (v - mu) * jax.lax.rsqrt(var + eps)
    return y * _f32(gamma) + _f32(beta)


def _rmsnorm_apply(v, gamma, *, eps: float = 1e-6):
    ms = jnp.mean(jnp.square(v), axis=-1, keepdims=True)
    return v * jax.lax.rsqrt(ms + eps) * _f32(gamma)


def _softmax_apply(v):
    m = jnp.max(v, axis=-1, keepdims=True)
    e = jnp.exp(v - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


# Masked-out attention scores are filled with a large-negative finite value
# (not -inf: exp(-inf - -inf) = nan on a fully masked row).  The streaming
# chained lowering treats anything below _MASK_FLOOR as masked when forming
# exp(z - m_new) — without the floor, a fully-masked tile whose running max
# is still _NEG_INF would contribute exp(0) = 1 per masked element.
_NEG_INF = -1e30
_MASK_FLOOR = -1e29


def _attn_mask_apply(v, *, causal: bool = True, window: int = 0,
                     offset: int = 0, _offsets=(0, 0)):
    """Causal / sliding-window score mask keyed on *global* element
    coordinates: row ``i`` (query, shifted by ``offset`` = S_kv - S_q so the
    last query row sees the full key range) may attend to column ``j`` (key)
    iff ``j <= i + offset`` (causal) and ``j > i + offset - window`` (when
    ``window > 0``).  Masked scores become ``_NEG_INF``.  Like the PRNG ops,
    the same function runs on full arrays (offsets (0, 0)) and on tiles (the
    Pallas lowering injects the tile's global offsets)."""
    r0, c0 = _offsets
    shape = jnp.shape(v)
    rows = r0 + offset + jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    cols = c0 + jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    keep = jnp.ones(shape, dtype=jnp.bool_)
    if causal:
        keep = jnp.logical_and(keep, cols <= rows)
    if window:
        keep = jnp.logical_and(keep, cols > rows - window)
    return jnp.where(keep, v, jnp.float32(_NEG_INF))


def _attn_mask_grad_apply(dv, *, causal: bool = True, window: int = 0,
                          offset: int = 0, _offsets=(0, 0)):
    """Cotangent of ``attn_mask``: dv flows only through kept positions (a
    dv-substitution grad, like dropout — the keep pattern is regenerated from
    the same attrs + coordinates, nothing is saved)."""
    r0, c0 = _offsets
    shape = jnp.shape(dv)
    rows = r0 + offset + jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    cols = c0 + jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    keep = jnp.ones(shape, dtype=jnp.bool_)
    if causal:
        keep = jnp.logical_and(keep, cols <= rows)
    if window:
        keep = jnp.logical_and(keep, cols > rows - window)
    return jnp.where(keep, dv, jnp.float32(0.0))


# --- derivative TPP semantics (fp32, full-row for the reducing ones) -------

def _relu_grad_apply(dv, x):
    return dv * (x > 0.0)


def _silu_grad_apply(dv, x):
    s = jax.nn.sigmoid(x)
    return dv * s * (1.0 + x * (1.0 - s))


def _sigmoid_grad_apply(dv, x):
    s = jax.nn.sigmoid(x)
    return dv * s * (1.0 - s)


def _layernorm_grad_apply(dv, z, gamma, *, eps: float = 1e-5):
    """dz of ``layernorm(z) * gamma + beta`` given dy=dv — the mean/rstd are
    *recomputed* from z (the Pallas lowering recovers them from the row-panel
    (sum, sum-sq) strip instead of re-reducing the panel)."""
    mu = jnp.mean(z, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(z - mu), axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    xhat = (z - mu) * rstd
    g = dv * _f32(gamma)
    return rstd * (g - jnp.mean(g, axis=-1, keepdims=True)
                   - xhat * jnp.mean(g * xhat, axis=-1, keepdims=True))


def _layernorm_gamma_grad_apply(dv, z, *, eps: float = 1e-5):
    """Per-element dgamma integrand ``dv * xhat(z)`` — the (N,) parameter
    cotangent is its column sum (done outside the fused region)."""
    mu = jnp.mean(z, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(z - mu), axis=-1, keepdims=True)
    return dv * (z - mu) * jax.lax.rsqrt(var + eps)


def _rmsnorm_grad_apply(dv, z, gamma, *, eps: float = 1e-6):
    ms = jnp.mean(jnp.square(z), axis=-1, keepdims=True)
    r = jax.lax.rsqrt(ms + eps)
    g = dv * _f32(gamma)
    n = z.shape[-1]
    return r * g - (r ** 3) * z * (
        jnp.sum(g * z, axis=-1, keepdims=True) / n)


def _rmsnorm_gamma_grad_apply(dv, z, *, eps: float = 1e-6):
    ms = jnp.mean(jnp.square(z), axis=-1, keepdims=True)
    return dv * z * jax.lax.rsqrt(ms + eps)


def _softmax_grad_apply(dv, z):
    p = _softmax_apply(z)
    return p * (dv - jnp.sum(dv * p, axis=-1, keepdims=True))


# --- callable grad rules (binary ops, norms — emit nodes via the sweep) ----
# A rule returns [(input_ref, cotangent_value_name_or_None), ...]; the sweep
# object exposes ``emit(op, inputs, attrs) -> name`` for new backward nodes.

def _grad_add(sweep, node, dv):
    return [(node.inputs[0], dv), (node.inputs[1], dv)]


def _grad_sub(sweep, node, dv):
    neg = sweep.emit("scale", (dv,), {"s": -1.0})
    return [(node.inputs[0], dv), (node.inputs[1], neg)]


def _grad_mul(sweep, node, dv):
    a, b = node.inputs
    return [(a, sweep.emit("mul", (dv, b))),
            (b, sweep.emit("mul", (dv, a)))]


def _grad_residual_add(sweep, node, dv):
    return [(node.inputs[0], dv), (node.inputs[1], dv)]


def _grad_bias_add(sweep, node, dv):
    return [(node.inputs[0], dv), (node.inputs[1], dv)]


def _grad_scale_rowvec(sweep, node, dv):
    v, s = node.inputs
    return [(v, sweep.emit("scale_rowvec", (dv, s))),
            (s, sweep.emit("mul", (dv, v)))]


def _grad_layernorm(sweep, node, dv):
    v, gamma, beta = node.inputs
    attrs = node.attr_dict()
    dz = sweep.emit("layernorm_grad", (dv, v, gamma), attrs)
    dgamma = sweep.emit("layernorm_gamma_grad", (dv, v), attrs)
    return [(v, dz), (gamma, dgamma), (beta, dv)]


def _grad_rmsnorm(sweep, node, dv):
    v, gamma = node.inputs
    attrs = node.attr_dict()
    return [(v, sweep.emit("rmsnorm_grad", (dv, v, gamma), attrs)),
            (gamma, sweep.emit("rmsnorm_gamma_grad", (dv, v), attrs))]


def _grad_softmax(sweep, node, dv):
    v = node.inputs[0]
    return [(v, sweep.emit("softmax_grad", (dv, v)))]


# Pointwise unary TPPs (fp32-in, fp32-out inside the fused region).
register_epilogue(EpilogueOp("identity", 1, (), lambda v: v,
                             flops_per_elem=0.0, grad="identity"))
register_epilogue(EpilogueOp("relu", 1, (), lambda v: jnp.maximum(v, 0.0),
                             grad="relu_grad"))
register_epilogue(EpilogueOp("gelu", 1, (), tpp.gelu, flops_per_elem=10.0,
                             grad="gelu_grad"))
register_epilogue(EpilogueOp("silu", 1, (), tpp.silu, flops_per_elem=5.0,
                             grad="silu_grad"))
register_epilogue(EpilogueOp(
    "sigmoid", 1, (), lambda v: jax.nn.sigmoid(v), flops_per_elem=4.0,
    grad="sigmoid_grad"))
register_epilogue(EpilogueOp(
    "scale", 1, (), lambda v, *, s: v * s, flops_per_elem=1.0, grad="scale"))

# Binary TPPs over two (M, N) values.
register_epilogue(EpilogueOp("add", 2, (), lambda a, b: a + b, grad=_grad_add))
register_epilogue(EpilogueOp("sub", 2, (), lambda a, b: a - b, grad=_grad_sub))
register_epilogue(EpilogueOp("mul", 2, (), lambda a, b: a * b, grad=_grad_mul))
register_epilogue(EpilogueOp(
    "residual_add", 1, ("tile",), lambda v, r: v + _f32(r),
    grad=_grad_residual_add))

# Row-broadcast vector TPPs.
register_epilogue(EpilogueOp(
    "bias_add", 1, ("rowvec",), lambda v, b: v + _f32(b), grad=_grad_bias_add))
register_epilogue(EpilogueOp(
    "scale_rowvec", 1, ("rowvec",), lambda v, s: v * _f32(s),
    grad=_grad_scale_rowvec))

# Masked dropout (pre-generated keep-mask — the legacy operand-streaming
# path, kept registered for backward compat; library graphs use
# ``dropout_rng``).  Dropout is self-adjoint: its grad is the *same* masked
# scaling applied to the cotangent — a named grad op with the
# dv-substitution arity.
register_epilogue(EpilogueOp(
    "dropout", 1, ("mask",), _dropout_apply, flops_per_elem=2.0,
    grad="dropout_grad"))

# In-kernel counter-based dropout (the TPP-paper primitive): a traced scalar
# seed operand replaces the (M, N) mask, bits are regenerated from
# (seed, salt, element coords) wherever the value lives — any tile of any
# schedule, forward or derived backward graph, draws identical bits.
register_epilogue(EpilogueOp(
    "dropout_rng", 1, ("scalar",), _dropout_rng_apply, flops_per_elem=28.0,
    grad="dropout_rng_grad", wants_offsets=True))

# Normalizations over the feature axis — row-panel epilogues.
register_epilogue(EpilogueOp(
    "layernorm", 1, ("rowvec", "rowvec"), _layernorm_apply,
    reduces="n", flops_per_elem=6.0, grad=_grad_layernorm, stats_input=0))
register_epilogue(EpilogueOp(
    "rmsnorm", 1, ("rowvec",), _rmsnorm_apply, reduces="n",
    flops_per_elem=4.0, grad=_grad_rmsnorm, stats_input=0))
register_epilogue(EpilogueOp(
    "softmax", 1, (), _softmax_apply, reduces="n", flops_per_elem=7.0,
    grad=_grad_softmax))

# Online softmax — the reducer a *chained* contraction root consumes.  Same
# full-row semantics as ``softmax`` (the XLA path and the standard row-panel
# lowering apply it to the finished row), but membership in ONLINE_REDUCERS
# licenses the streaming chained lowering: instead of staging the (M, N)
# row panel, the kernel carries a (running max, running sum) statistics
# strip and rescales the chain accumulator by exp(m_prev - m_new) whenever a
# new N tile raises the max — flash attention's online-softmax recurrence as
# a reusable IR-level reducer.
register_epilogue(EpilogueOp(
    "softmax_online", 1, (), _softmax_apply, reduces="n", flops_per_elem=9.0,
    grad=_grad_softmax, stats_input=0))

# Coordinate-keyed attention score mask (causal / sliding window).  Like the
# counter-PRNG dropout, it regenerates its pattern from global element
# coordinates (wants_offsets) so every tile of every schedule — forward or
# backward — masks identically with no (M, N) mask operand.
register_epilogue(EpilogueOp(
    "attn_mask", 1, (), _attn_mask_apply, flops_per_elem=4.0,
    grad="attn_mask_grad", wants_offsets=True))
register_epilogue(EpilogueOp(
    "attn_mask_grad", 1, (), _attn_mask_grad_apply, flops_per_elem=4.0,
    wants_offsets=True))

#: Reducing ops whose recurrence the chained Pallas lowering knows how to
#: stream (running max + running sum).  A chained root's lhs must name a
#: node using one of these.
ONLINE_REDUCERS = frozenset({"softmax_online"})

# Derivative TPPs (fusion.autodiff's backward epilogue DAGs).  The pointwise
# ones take (dv, primal-input); the reducing ones recompute the row
# statistics of their primal input via the same row-panel strip the forward
# norms use (``stats_input=1``: the staged z panel feeds (sum, sum-sq)).
register_epilogue(EpilogueOp("relu_grad", 2, (), _relu_grad_apply,
                             flops_per_elem=2.0))
register_epilogue(EpilogueOp("gelu_grad", 2, (), tpp.gelu_grad,
                             flops_per_elem=14.0))
register_epilogue(EpilogueOp("silu_grad", 2, (), _silu_grad_apply,
                             flops_per_elem=8.0))
register_epilogue(EpilogueOp("sigmoid_grad", 2, (), _sigmoid_grad_apply,
                             flops_per_elem=6.0))
register_epilogue(EpilogueOp("dropout_grad", 1, ("mask",), _dropout_apply,
                             flops_per_elem=2.0))
# dropout_rng is self-adjoint too: the backward node carries the same
# (rate, salt) attrs and seed operand, so it REGENERATES the forward draw —
# no mask is ever saved between forward and backward.
register_epilogue(EpilogueOp(
    "dropout_rng_grad", 1, ("scalar",), _dropout_rng_apply,
    flops_per_elem=28.0, wants_offsets=True))
register_epilogue(EpilogueOp(
    "layernorm_grad", 2, ("rowvec",), _layernorm_grad_apply, reduces="n",
    flops_per_elem=12.0, stats_input=1))
register_epilogue(EpilogueOp(
    "layernorm_gamma_grad", 2, (), _layernorm_gamma_grad_apply, reduces="n",
    flops_per_elem=8.0, stats_input=1))
register_epilogue(EpilogueOp(
    "rmsnorm_grad", 2, ("rowvec",), _rmsnorm_grad_apply, reduces="n",
    flops_per_elem=10.0, stats_input=1))
register_epilogue(EpilogueOp(
    "rmsnorm_gamma_grad", 2, (), _rmsnorm_gamma_grad_apply, reduces="n",
    flops_per_elem=6.0, stats_input=1))
register_epilogue(EpilogueOp(
    "softmax_grad", 2, (), _softmax_grad_apply, reduces="n",
    flops_per_elem=10.0))


# ---------------------------------------------------------------------------
# The graph
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TppGraph:
    """Contraction roots + an epilogue DAG of TPP nodes.

    ``roots`` defaults to the single root ``acc = lhs @ rhs`` derived from
    the unique lhs/rhs operands (the PR-1 single-contraction form).
    ``outputs`` defaults to the last node's value (or the sole root for an
    empty epilogue); multi-output graphs return the named values stacked on
    a leading axis.
    """

    name: str
    operands: tuple[OperandSpec, ...]
    nodes: tuple[Node, ...] = ()
    roots: tuple[ContractionRoot, ...] = ()
    outputs: tuple[str, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "operands", tuple(self.operands))
        object.__setattr__(self, "nodes", tuple(self.nodes))
        if not self.roots:
            # single-contraction form: derive "acc" from the lhs/rhs operands
            lhs = [o.name for o in self.operands if o.kind == "lhs"]
            rhs = [o.name for o in self.operands if o.kind == "rhs"]
            if len(lhs) != 1 or len(rhs) != 1:
                raise FusionLegalityError(
                    f"graph {self.name!r}: without explicit roots the graph "
                    f"needs exactly one lhs and one rhs operand, got "
                    f"{len(lhs)} lhs / {len(rhs)} rhs — declare roots=",
                code="TPP201")
            object.__setattr__(
                self, "roots", (ContractionRoot("acc", lhs[0], rhs[0]),))
        else:
            object.__setattr__(self, "roots", tuple(self.roots))
        if not self.outputs:
            last = self.nodes[-1].name if self.nodes else self.roots[0].name
            object.__setattr__(self, "outputs", (last,))
        else:
            object.__setattr__(self, "outputs", tuple(self.outputs))
        self.validate()

    # -- views ----------------------------------------------------------
    def operand(self, name: str) -> OperandSpec:
        for o in self.operands:
            if o.name == name:
                return o
        raise KeyError(name)

    def root(self, name: str) -> ContractionRoot:
        for r in self.roots:
            if r.name == name:
                return r
        raise KeyError(name)

    @property
    def lhs(self) -> OperandSpec:
        """The first root's lhs operand (single-root convenience view)."""
        return self.operand(self.roots[0].lhs)

    @property
    def rhs(self) -> OperandSpec:
        """The first root's rhs operand (single-root convenience view)."""
        return self.operand(self.roots[0].rhs)

    @property
    def contraction_operands(self) -> tuple[OperandSpec, ...]:
        """lhs/rhs/crhs operands in canonical (root-declaration) order,
        shared operands listed once — the packing order of the lowering.  A
        chained root contributes only its rhs (its lhs is a computed
        value)."""
        seen: dict[str, OperandSpec] = {}
        for r in self.roots:
            for nm in ((r.rhs,) if r.chained else (r.lhs, r.rhs)):
                if nm not in seen:
                    seen[nm] = self.operand(nm)
        return tuple(seen.values())

    @property
    def epilogue_operands(self) -> tuple[OperandSpec, ...]:
        return tuple(o for o in self.operands
                     if o.kind not in ("lhs", "rhs", "crhs"))

    def chained_root(self) -> Optional[ContractionRoot]:
        """The graph's chained root, or None (validation allows at most
        one)."""
        for r in self.roots:
            if r.chained:
                return r
        return None

    @property
    def base_roots(self) -> tuple[ContractionRoot, ...]:
        """Non-chained roots — the GEMMs the shared (M, K, N) nest carries
        directly."""
        return tuple(r for r in self.roots if not r.chained)

    def reducing_node(self) -> Optional[Node]:
        for nd in self.nodes:
            if EPILOGUE_OPS[nd.op].reduces is not None:
                return nd
        return None

    def post_reduce_nodes(self) -> tuple[Node, ...]:
        """Pointwise nodes *after* the reducing node — they execute on the
        finished full-row panel in the last-N-visit branch (empty when the
        graph has no reducing node)."""
        red = self.reducing_node()
        if red is None:
            return ()
        idx = self.nodes.index(red)
        return self.nodes[idx + 1:]

    def staged_values(self) -> tuple[str, ...]:
        """Computed value inputs of the reducing node (root accumulators or
        pre-reduce node outputs) — each is staged as a VMEM row panel by the
        Pallas lowering so the reduction sees full rows."""
        red = self.reducing_node()
        if red is None:
            return ()
        return self.staged_values_of(red, self.nodes.index(red))

    def staged_values_of(self, red: Node, idx: int) -> tuple[str, ...]:
        op = EPILOGUE_OPS[red.op]
        computed = set(self.root_names) | {nd.name for nd in self.nodes[:idx]}
        if len(self.roots) == 1:
            computed.add("acc")
        return tuple(dict.fromkeys(
            r for r in red.inputs[:op.value_arity] if r in computed))

    def row_resident_operands(self) -> frozenset[str]:
        """tile/mask operands consumed as *values* by the reducing node or a
        post-reduce node: they must be mapped as full-row (bm, N) blocks so
        the close branch sees complete rows (pre-reduce consumers slice the
        current N tile out of the row block)."""
        red = self.reducing_node()
        if red is None:
            return frozenset()
        names = set()
        idx = self.nodes.index(red)
        for nd in self.nodes[idx:]:
            for ref in nd.inputs:   # value AND operand positions
                try:
                    spec = self.operand(ref)
                except KeyError:
                    continue
                if spec.kind in ("tile", "mask"):
                    names.add(ref)
        return frozenset(names)

    @property
    def operand_names(self) -> tuple[str, ...]:
        return tuple(o.name for o in self.operands)

    @property
    def root_names(self) -> tuple[str, ...]:
        return tuple(r.name for r in self.roots)

    def resolve_acc(self, ref: str) -> str:
        """Map the ``"acc"`` alias to the sole root's name (identity for
        everything else)."""
        if ref == "acc" and len(self.roots) == 1:
            return self.roots[0].name
        return ref

    def epilogue_flops_per_elem(self) -> float:
        """Summed per-output-element VPU flop estimate of the epilogue DAG —
        the perf model's fused-epilogue compute term."""
        return float(sum(EPILOGUE_OPS[nd.op].flops_per_elem for nd in self.nodes))

    # -- validation ------------------------------------------------------
    def validate(self):
        names = [o.name for o in self.operands]
        if len(set(names)) != len(names):
            raise FusionLegalityError(
                f"graph {self.name!r}: duplicate operand names",
                code="TPP211")

        # roots: unique names, no shadowing, lhs/rhs of the declared kinds
        root_names = [r.name for r in self.roots]
        if len(set(root_names)) != len(root_names):
            raise FusionLegalityError(
                f"graph {self.name!r}: duplicate root names {root_names}",
                code="TPP211")
        chained = [r for r in self.roots if r.chained]
        for r in self.roots:
            if r.name in names or (r.name == "acc" and len(self.roots) > 1):
                raise FusionLegalityError(
                    f"graph {self.name!r}: root name {r.name!r} shadows an "
                    "operand or the single-root 'acc' alias", code="TPP211")
            # a chained root's lhs is a computed value (validated against the
            # reducing node below, once nodes are known), not an operand
            sides = ((("rhs", r.rhs, "crhs"),) if r.chained
                     else (("lhs", r.lhs, "lhs"), ("rhs", r.rhs, "rhs")))
            for side, nm, kind in sides:
                try:
                    spec = self.operand(nm)
                except KeyError:
                    raise FusionLegalityError(
                        f"graph {self.name!r}: root {r.name!r} {side} operand "
                        f"{nm!r} is not declared", code="TPP201") from None
                if spec.kind != kind:
                    raise FusionLegalityError(
                        f"graph {self.name!r}: root {r.name!r} {side} operand "
                        f"{nm!r} must have kind {kind!r}, got {spec.kind!r}",
                        code="TPP213" if kind == "crhs" else "TPP210")
        if len(chained) > 1:
            raise FusionLegalityError(
                f"graph {self.name!r}: at most one chained root per graph "
                f"(one chain accumulator + statistics strip), got "
                f"{[r.name for r in chained]}", code="TPP212")
        if chained and len(self.roots) == len(chained):
            raise FusionLegalityError(
                f"graph {self.name!r}: a chained root needs at least one "
                "base root to consume — nothing produces the reduced panel",
                code="TPP212")
        rooted = {nm for r in self.roots
                  for nm in ((r.rhs,) if r.chained else (r.lhs, r.rhs))}
        for o in self.operands:
            if o.kind in ("lhs", "rhs") and o.name not in rooted:
                raise FusionLegalityError(
                    f"graph {self.name!r}: {o.kind} operand {o.name!r} is not "
                    "referenced by any contraction root", code="TPP201")
            if o.kind == "crhs" and o.name not in rooted:
                raise FusionLegalityError(
                    f"graph {self.name!r}: crhs operand {o.name!r} is not "
                    "consumed by any chained root — crhs operands exist only "
                    "as chained-contraction rhs", code="TPP213")

        visible = set(names) | set(root_names)
        if len(self.roots) == 1:
            visible.add("acc")
        reduce_node: Optional[Node] = None
        post_visible: set[str] = set()   # values a post-reduce node may read
        for i, nd in enumerate(self.nodes):
            op = EPILOGUE_OPS.get(nd.op)
            if op is None:
                raise FusionLegalityError(
                    f"graph {self.name!r}: node {nd.name!r} uses unregistered "
                    f"epilogue op {nd.op!r}", code="TPP209")
            want = op.value_arity + len(op.operand_kinds)
            if len(nd.inputs) != want:
                raise FusionLegalityError(
                    f"graph {self.name!r}: node {nd.name!r} ({nd.op}) takes "
                    f"{want} inputs, got {len(nd.inputs)}", code="TPP204")
            for ref in nd.inputs:
                if ref not in visible:
                    raise FusionLegalityError(
                        f"graph {self.name!r}: node {nd.name!r} references "
                        f"unknown value {ref!r} (nodes must be topologically "
                        "ordered)", code="TPP201")
            # trailing inputs must be operands of the declared kinds
            for ref, kind in zip(nd.inputs[op.value_arity:], op.operand_kinds):
                try:
                    spec = self.operand(ref)
                except KeyError:
                    raise FusionLegalityError(
                        f"graph {self.name!r}: node {nd.name!r} ({nd.op}) "
                        f"input {ref!r} must be a graph operand of kind "
                        f"{kind!r}", code="TPP210") from None
                if spec.kind != kind:
                    raise FusionLegalityError(
                        f"graph {self.name!r}: node {nd.name!r} ({nd.op}) "
                        f"expects a {kind!r} operand, {ref!r} is "
                        f"{spec.kind!r}", code="TPP210")
            if reduce_node is not None:
                # post-reduce band: pointwise nodes on the finished full-row
                # panel.  They may read operands (mapped full-row), the
                # reducing value, the reducer's staged inputs (VMEM-resident
                # panels), and later post-reduce values — but NOT other
                # pre-reduce computed values or root accumulators, which
                # only ever hold the current N tile.
                if op.reduces is not None:
                    raise FusionLegalityError(
                        f"graph {self.name!r}: node {nd.name!r} ({nd.op}) — "
                        "at most one reducing epilogue per graph (one row "
                        "panel + statistics strip)", code="TPP202")
                for ref in nd.inputs[:op.value_arity]:
                    if ref not in post_visible and ref not in names:
                        raise FusionLegalityError(
                            f"graph {self.name!r}: post-reduce node "
                            f"{nd.name!r} ({nd.op}) references {ref!r}, "
                            "which is not full-row resident after the "
                            f"reducing node ({reduce_node.op}) closes — only "
                            "operands, the reducing value, its staged "
                            "inputs, and later post-reduce values are",
                            code="TPP206")
                post_visible.add(nd.name)
            elif op.reduces is not None:
                reduce_node = nd
                post_visible = {nd.name, *self.staged_values_of(nd, i)}
            if nd.name in visible:
                raise FusionLegalityError(
                    f"graph {self.name!r}: node name {nd.name!r} shadows an "
                    "earlier value", code="TPP211")
            visible.add(nd.name)

        # crhs operands feed chained roots only — a node consuming one as a
        # value would read the (N, N2) chain operand at (M, N) tile shape
        for nd in self.nodes:
            op = EPILOGUE_OPS[nd.op]
            for ref in nd.inputs[:op.value_arity]:
                try:
                    spec = self.operand(ref)
                except KeyError:
                    continue
                if spec.kind == "crhs":
                    raise FusionLegalityError(
                        f"graph {self.name!r}: node {nd.name!r} consumes "
                        f"crhs operand {ref!r} as a value — crhs operands "
                        "are chained-contraction rhs only", code="TPP213")

        ch = chained[0] if chained else None
        if ch is not None:
            if self.roots[-1] is not ch:
                raise FusionLegalityError(
                    f"graph {self.name!r}: chained root {ch.name!r} must be "
                    "declared after every base root — it consumes their "
                    "reduced panel", code="TPP212")
            if reduce_node is None or ch.lhs != reduce_node.name:
                raise FusionLegalityError(
                    f"graph {self.name!r}: chained root {ch.name!r} lhs "
                    f"{ch.lhs!r} must name the graph's reducing node"
                    + (f" ({reduce_node.name!r})" if reduce_node is not None
                       else " — the graph has none"), code="TPP212")
            if reduce_node.op not in ONLINE_REDUCERS:
                raise FusionLegalityError(
                    f"graph {self.name!r}: chained root {ch.name!r} consumes "
                    f"reducer {reduce_node.op!r}, which has no streaming "
                    f"(running max, running sum) recurrence — online "
                    f"reducers: {sorted(ONLINE_REDUCERS)}", code="TPP212")
            if self.nodes[-1] is not reduce_node:
                raise FusionLegalityError(
                    f"graph {self.name!r}: chained root {ch.name!r} — no "
                    "post-reduce nodes allowed: the reduced panel is never "
                    "materialized, it streams straight into the chain "
                    "accumulator", code="TPP212")
            if self.outputs != (ch.name,):
                raise FusionLegalityError(
                    f"graph {self.name!r}: a chained graph's only output is "
                    f"the chained root ({ch.name!r}); base accumulators and "
                    f"the reduced panel are never materialized — got outputs "
                    f"{self.outputs}", code="TPP212")
            for nd in self.nodes:
                if ch.name in nd.inputs:
                    raise FusionLegalityError(
                        f"graph {self.name!r}: node {nd.name!r} reads chained "
                        f"root {ch.name!r} — the chain accumulator closes "
                        "only at the final N visit, after every node has "
                        "run", code="TPP212")

        # outputs: computed values only (roots/nodes, not plain operands —
        # the lowering's output write has no operand fallback); in a reducing
        # graph every output is written in the close branch, so it must be
        # the reducing value or a post-reduce value
        if len(set(self.outputs)) != len(self.outputs):
            raise FusionLegalityError(
                f"graph {self.name!r}: duplicate outputs {self.outputs}",
                code="TPP211")
        computed = visible - set(names)
        for ref in self.outputs:
            if ref not in computed:
                raise FusionLegalityError(
                    f"graph {self.name!r}: output {ref!r} names no root, "
                    "node, or the 'acc' alias", code="TPP208")
            if ch is not None and ref == ch.name:
                continue    # the chained close IS the full-row-final write
            if reduce_node is not None and ref not in post_visible:
                raise FusionLegalityError(
                    f"graph {self.name!r}: output {ref!r} is not full-row "
                    f"resident when the reducing epilogue "
                    f"({reduce_node.op}) closes — outputs of a reducing "
                    "graph must be the reducing value or post-reduce values",
                    code="TPP208")

    # -- convenience builder --------------------------------------------
    @classmethod
    def chain(cls, name: str, ops: list, operands: list) -> "TppGraph":
        """Build a straight-line graph: each entry of ``ops`` is
        ``(op_name, extra_input_names, attrs_dict)`` (or just the op name),
        chained on the previous value starting from ``"acc"``."""
        specs = tuple(OperandSpec(n, k) for n, k in operands)
        nodes, prev = [], "acc"
        for i, entry in enumerate(ops):
            if isinstance(entry, str):
                op_name, extra, attrs = entry, (), {}
            else:
                op_name, extra, attrs = entry
            nd = Node(
                name=f"n{i}_{op_name}",
                op=op_name,
                inputs=(prev, *extra),
                attrs=tuple(sorted(attrs.items())),
            )
            nodes.append(nd)
            prev = nd.name
        return cls(name=name, operands=specs, nodes=tuple(nodes))

    def describe(self) -> str:
        out = [f"TppGraph {self.name!r}:"]
        for r in self.roots:
            def t(nm):
                try:
                    return nm + "^T" if self.operand(nm).trans else nm
                except KeyError:
                    return nm   # chained lhs: a computed value
            kind = "chain_gemm" if r.chained else "gemm"
            out.append(f"  {r.name} = {kind}({t(r.lhs)}, {t(r.rhs)})")
        for nd in self.nodes:
            attrs = ", ".join(f"{k}={v}" for k, v in nd.attrs)
            out.append(
                f"  {nd.name} = {nd.op}({', '.join(nd.inputs)}"
                + (f"; {attrs}" if attrs else "") + ")")
        ret = ", ".join(self.outputs)
        out.append(f"  return {'stack(' + ret + ')' if len(self.outputs) > 1 else ret}")
        return "\n".join(out)


# ---------------------------------------------------------------------------
# Graph simplification — run by ``fusion.compile`` before lowering
# ---------------------------------------------------------------------------

def _node_is_noop(nd: Node) -> bool:
    if nd.op == "identity":
        return True
    if nd.op in ("dropout", "dropout_rng"):
        return float(nd.attr_dict().get("rate", 0.0)) <= 0.0
    return False


def simplify_graph(graph: TppGraph) -> TppGraph:
    """Drop no-op epilogue nodes (``identity``, rate-0 ``dropout`` /
    ``dropout_rng``) and any operand no longer referenced by a node, root,
    or output.  A rate-0 fused-output graph therefore lowers with *no*
    keep-mask (or seed) operand — no all-ones (M, N) mask streamed through
    the kernel.  Value semantics are preserved exactly: a dropped node
    forwards its (rewritten) value input.  Returns ``graph`` itself when
    there is nothing to do."""
    repl: dict[str, str] = {}
    kept: list[Node] = []
    for nd in graph.nodes:
        inputs = tuple(repl.get(r, r) for r in nd.inputs)
        # a no-op that IS a named output keeps its node: rewriting the output
        # instead could leave an operand-named output or collide with another
        # output it aliases — both invalid graphs
        if _node_is_noop(nd) and nd.name not in graph.outputs:
            repl[nd.name] = inputs[0]
            continue
        kept.append(nd if inputs == nd.inputs
                    else dataclasses.replace(nd, inputs=inputs))
    outputs = tuple(repl.get(r, r) for r in graph.outputs)

    referenced = {nm for r in graph.roots for nm in (r.lhs, r.rhs)}
    referenced.update(outputs)
    for nd in kept:
        referenced.update(nd.inputs)
    operands = tuple(o for o in graph.operands if o.name in referenced)

    if (len(kept) == len(graph.nodes) and operands == graph.operands
            and outputs == graph.outputs):
        return graph
    return TppGraph(name=graph.name, operands=operands, nodes=tuple(kept),
                    roots=graph.roots, outputs=outputs)
