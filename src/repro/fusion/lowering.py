"""Lower a ``TppGraph`` three ways (paper Fig. 1):

  * ``path="xla"``    — the reference: compose the ``core.tpp`` functions on
    full arrays and let XLA fuse them (the paper's "straightforward"
    framework path);
  * ``path="pallas"`` — ONE fused Pallas kernel: every contraction root runs
    under the same PARLOOPER ``loop_spec_string`` (letters ``a``=K reduction,
    ``b``=M, ``c``=N, exactly ``kernels.brgemm``) with one fp32 accumulator
    tile per root — a shared lhs operand is loaded once per (M, K) visit and
    feeds all its roots' MXU issues — the epilogue DAG is applied to the
    VMEM-resident accumulator tiles, and normalizing epilogues (layernorm /
    rmsnorm / softmax over N) use the row-panel statistics trick of
    ``kernels.fused_output``: the pre-norm row panel is staged in VMEM
    scratch, (sum, sum-of-squares) statistics accumulate per N tile, and the
    normalization equation is applied to the finished panel on the last N
    visit.  Multi-output graphs write each output value into a leading
    stacking axis → (R, M, N) (fused QKV);
  * the cost path lives in ``fusion.cost`` (perf-model + autotune hook).

``compile`` first runs ``simplify_graph`` (dropping identity / rate-0 dropout
nodes and now-unreferenced operands); operands the simplification removed are
still *accepted* at call time and ignored, so callers keep one call signature
per graph family.

Legality: besides the usual K-innermost requirement
(``validate_reduction_innermost``), a normalizing epilogue pins the N loop to
the nest's innermost band *under* every M level — a row's tiles must be
visited consecutively for its statistics to close before the panel is reused.
``validate_epilogue_band`` diagnoses schedules that violate this instead of
producing silently wrong kernels (the paper leaves such legality to the user).
"""
from __future__ import annotations

import contextlib
import logging
import os
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import tpp
from repro.core.autotune import _freeze as _freeze_kw
from repro.obs import metrics as obs_metrics, trace as obs_trace
from repro.core.loops import LoopSpec, ThreadedLoop
from repro.core.pallas_lowering import (TensorMap, make_pallas_fn, plan_pallas,
                                        validate_reduction_innermost)
from repro.fusion.graph import (EPILOGUE_OPS, FusionLegalityError, TppGraph,
                                _MASK_FLOOR, _NEG_INF, simplify_graph)

__all__ = [
    "compile", "compile_for_backend", "validate_epilogue_band",
    "build_nest_inputs", "DEFAULT_SPEC",
    "fallback_blocklist", "clear_fallback_blocklist", "force_pallas_failure",
]

DEFAULT_SPEC = "bca"  # M, N outer; K (reduction) innermost — output-stationary


# ---------------------------------------------------------------------------
# Legality
# ---------------------------------------------------------------------------

def validate_epilogue_band(nest, graph: TppGraph, *, m_letter="b", n_letter="c"):
    """A normalizing epilogue reduces over N; its row panel closes only when
    all N tiles of a row are visited consecutively.  Reject schedules where
    any N level sits outside (above) an M level, where the N loop is
    parallelized (statistics accumulate sequentially), or where N is sharded
    over a mesh axis (the row statistics would be partial per shard)."""
    from repro.analysis import footprint

    footprint.enforce(
        footprint.check_epilogue_band(nest, graph, m_letter=m_letter,
                                      n_letter=n_letter),
        exc=FusionLegalityError,
    )


# ---------------------------------------------------------------------------
# Shared nest construction (also used by fusion.cost)
# ---------------------------------------------------------------------------

def build_nest_inputs(graph: TppGraph, m: int, k: int, n: int,
                      tiles: tuple[int, int, int],
                      block_steps: Optional[dict] = None, *,
                      rhs_widths: Optional[dict] = None,
                      chain_n2: Optional[int] = None):
    """LoopSpecs + TensorMaps for lowering ``graph`` at problem size
    (M, K, N) with base tiles (bm, bk, bn).  Operand order is
    ``[*contraction_operands, *epilogue_operands]`` (shared lhs operands
    mapped — and fetched — once); row vectors are fully VMEM-resident
    ``(1, n)`` blocks, (M, N) operands are tiled with the output — except
    operands consumed by the reducing node or a post-reduce node, which get
    full-row ``(bm, n)`` blocks (the close branch needs complete rows).
    Contraction operands with ``trans=True`` are mapped with their stored
    (transposed) layout — lhs (K, M), rhs (N, K) — and the kernel issues the
    MXU op with swapped contraction dims instead of materializing a
    transpose.  A multi-output graph's out map carries a leading unindexed
    stacking axis of extent R (array shape ``(R, M, N)``).

    ``rhs_widths`` maps rhs operand names to a *narrow* N width ``w < n``
    (per-root widths — GQA's K/V projections): the whole stored array is
    VMEM-resident every call (``(bk·steps, w)`` blocks, no ``c`` letter) and
    the kernel slices the live N tile out of it, skipping tiles past ``w``.
    ``chain_n2`` is the chained contraction's output width: crhs operands
    map as ``(bn·steps, n2)`` blocks walked by ``c``, and the (single)
    output of a chained graph maps full-width ``(bm, n2)`` rows."""
    bm, bk, bn = tiles
    if m % bm or k % bk or n % bn:
        raise FusionLegalityError(
            f"graph {graph.name!r}: problem ({m},{k},{n}) not divisible by "
            f"tiles ({bm},{bk},{bn}) — pick tiles dividing the problem "
            "shape (pick_tiles chooses divisors automatically)",
            code="TPP108")
    mb, kb, nb = m // bm, k // bk, n // bn
    block_steps = block_steps or {}
    rhs_widths = rhs_widths or {}
    if graph.chained_root() is not None and chain_n2 is None:
        chain_n2 = k   # attention default: the chain restores the lhs width
    loops = [
        LoopSpec(0, kb, 1, block_steps=tuple(block_steps.get("a", ())), name="K"),
        LoopSpec(0, mb, 1, block_steps=tuple(block_steps.get("b", ())), name="M"),
        LoopSpec(0, nb, 1, block_steps=tuple(block_steps.get("c", ())), name="N"),
    ]
    row_res = graph.row_resident_operands()
    in_maps = []
    for spec in graph.contraction_operands:
        if spec.kind == "lhs":
            in_maps.append(TensorMap(("a", "b"), (bk, bm), layout="flat")
                           if spec.trans
                           else TensorMap(("b", "a"), (bm, bk), layout="flat"))
        elif spec.kind == "crhs":
            # stored (N, N2), walked by the N loop, full chain width visible
            in_maps.append(TensorMap(("c", None), (bn, chain_n2),
                                     layout="flat"))
        elif spec.name in rhs_widths:
            # narrow rhs: whole stored array resident, no N-loop indexing
            w = rhs_widths[spec.name]
            in_maps.append(TensorMap((None, "a"), (w, bk), layout="flat")
                           if spec.trans
                           else TensorMap(("a", None), (bk, w), layout="flat"))
        else:
            in_maps.append(TensorMap(("c", "a"), (bn, bk), layout="flat")
                           if spec.trans
                           else TensorMap(("a", "c"), (bk, bn), layout="flat"))
    for spec in graph.epilogue_operands:
        if spec.kind in ("tile", "mask"):
            in_maps.append(
                TensorMap(("b", None), (bm, n), layout="flat")
                if spec.name in row_res
                else TensorMap(("b", "c"), (bm, bn), layout="flat"))
        elif spec.kind == "scalar":   # traced scalar (PRNG seed) — one elem
            in_maps.append(TensorMap((None, None), (1, 1), layout="flat"))
        else:  # rowvec — whole vector visible every call (norms need full N)
            in_maps.append(TensorMap((None, None), (1, n), layout="flat"))
    n_out = len(graph.outputs)
    if graph.chained_root() is not None:
        # single output (validated), full chain width per row block
        out_map = TensorMap(("b", None), (bm, chain_n2), layout="flat")
    elif graph.reducing_node() is not None:
        out_map = (TensorMap((None, "b", None), (n_out, bm, n), layout="flat")
                   if n_out > 1
                   else TensorMap(("b", None), (bm, n), layout="flat"))
    elif n_out > 1:
        out_map = TensorMap((None, "b", "c"), (n_out, bm, bn), layout="flat")
    else:
        out_map = TensorMap(("b", "c"), (bm, bn), layout="flat")
    return loops, in_maps, out_map


def _pack_operands(graph: TppGraph, operands: dict, ignore=frozenset()):
    """Canonically order ([*contraction-operands, *epilogue-operands]) and
    reshape call-time operands: rowvecs (n,) → (1, n).  Canonical order is
    independent of the graph's declaration order — the Pallas lowering's
    TensorMaps are built in the same order.  Names in ``ignore`` (operands a
    simplification pass removed from the graph) are accepted and dropped."""
    packed = []
    for spec in graph.contraction_operands + graph.epilogue_operands:
        if spec.name not in operands:
            raise TypeError(
                f"graph {graph.name!r}: missing operand {spec.name!r}; "
                f"expected {graph.operand_names}")
        v = operands[spec.name]
        if spec.kind == "rowvec":
            v = v.reshape(1, -1)
        elif spec.kind == "scalar":
            v = jnp.asarray(v).reshape(1, 1)
        packed.append(v)
    extra = set(operands) - set(graph.operand_names) - set(ignore)
    if extra:
        raise TypeError(f"graph {graph.name!r}: unexpected operands {sorted(extra)}")
    return packed


# ---------------------------------------------------------------------------
# Path 1: XLA reference — compose core.tpp functions, let XLA fuse
# ---------------------------------------------------------------------------

def _compile_xla(graph: TppGraph, *, out_dtype=None, ignore=frozenset()):
    def fn(**operands):
        _pack_operands(graph, operands, ignore)  # validates the operand set
        base = graph.base_roots
        x = operands[base[0].lhs]
        env = {}
        for root in base:
            a, b = operands[root.lhs], operands[root.rhs]
            if graph.operand(root.lhs).trans:
                a = a.T
            if graph.operand(root.rhs).trans:
                b = b.T
            env[root.name] = tpp.gemm(a, b, beta=0.0, out_dtype=jnp.float32)
        if len(graph.roots) == 1:
            env["acc"] = env[graph.roots[0].name]

        def value(ref):
            if ref in env:
                return env[ref]
            spec = graph.operand(ref)
            v = operands[ref]
            return (v if spec.kind in ("mask", "scalar")
                    else v.astype(jnp.float32))

        for nd in graph.nodes:
            op = EPILOGUE_OPS[nd.op]
            # wants_offsets ops see the full (M, N) array here — the global
            # coordinates ARE the local ones, so the (0, 0) default applies
            env[nd.name] = op.apply(*(value(r) for r in nd.inputs),
                                    **nd.attr_dict())
        # a chained root consumes the reduced panel, so it evaluates AFTER
        # the epilogue DAG: the composed reference is softmax-then-matmul,
        # mathematically identical to the streamed online recurrence
        for root in graph.roots:
            if root.chained:
                env[root.name] = tpp.gemm(env[root.lhs],
                                          operands[root.rhs].astype(
                                              jnp.float32),
                                          beta=0.0, out_dtype=jnp.float32)
        odt = out_dtype or x.dtype
        if len(graph.outputs) > 1:
            outs = [env[o] for o in graph.outputs]
            # per-root N widths (GQA): narrow roots zero-pad to the stack
            # width, matching the Pallas kernel's never-touched acc columns
            wmax = max(o.shape[-1] for o in outs)
            outs = [o if o.shape[-1] == wmax
                    else jnp.pad(o, ((0, 0), (0, wmax - o.shape[-1])))
                    for o in outs]
            return jnp.stack(outs).astype(odt)
        return env[graph.outputs[0]].astype(odt)

    return fn


# ---------------------------------------------------------------------------
# Path 2: one fused Pallas kernel
# ---------------------------------------------------------------------------

# Reducing ops whose close-branch formula recovers the row statistics from
# the (sum, sum-of-squares) strip accumulated tile-by-tile over the staged
# stats panel, instead of re-reducing the finished panel.  ``vals`` are the
# op's full-row value inputs, ``params`` its full-row (1, n) operand inputs.

def _ln_close(vals, params, stats, n, attrs):
    (z,) = vals
    gamma, beta = params
    mu = stats[:, 0:1] / n
    var = jnp.maximum(stats[:, 1:2] / n - mu * mu, 0.0)
    y = (z - mu) * jax.lax.rsqrt(var + attrs.get("eps", 1e-5))
    return y * gamma + beta


def _rms_close(vals, params, stats, n, attrs):
    (z,) = vals
    ms = stats[:, 1:2] / n
    return z * jax.lax.rsqrt(ms + attrs.get("eps", 1e-6)) * params[0]


def _ln_grad_close(vals, params, stats, n, attrs):
    dv, z = vals
    mu = stats[:, 0:1] / n
    var = jnp.maximum(stats[:, 1:2] / n - mu * mu, 0.0)
    rstd = jax.lax.rsqrt(var + attrs.get("eps", 1e-5))
    xhat = (z - mu) * rstd
    g = dv * params[0]
    return rstd * (g - jnp.mean(g, axis=1, keepdims=True)
                   - xhat * jnp.mean(g * xhat, axis=1, keepdims=True))


def _ln_gamma_close(vals, params, stats, n, attrs):
    dv, z = vals
    mu = stats[:, 0:1] / n
    var = jnp.maximum(stats[:, 1:2] / n - mu * mu, 0.0)
    return dv * (z - mu) * jax.lax.rsqrt(var + attrs.get("eps", 1e-5))


def _rms_grad_close(vals, params, stats, n, attrs):
    dv, z = vals
    ms = stats[:, 1:2] / n
    r = jax.lax.rsqrt(ms + attrs.get("eps", 1e-6))
    g = dv * params[0]
    return r * g - (r ** 3) * z * (jnp.sum(g * z, axis=1, keepdims=True) / n)


def _rms_gamma_close(vals, params, stats, n, attrs):
    dv, z = vals
    ms = stats[:, 1:2] / n
    return dv * z * jax.lax.rsqrt(ms + attrs.get("eps", 1e-6))


_STATS_CLOSE = {
    "layernorm": _ln_close,
    "rmsnorm": _rms_close,
    "layernorm_grad": _ln_grad_close,
    "layernorm_gamma_grad": _ln_gamma_close,
    "rmsnorm_grad": _rms_grad_close,
    "rmsnorm_gamma_grad": _rms_gamma_close,
}


def contraction_operand_values(graph: TppGraph) -> frozenset[str]:
    """Contraction (lhs/rhs) operands referenced as epilogue *values*.  The
    XLA path supports them (full arrays); the Pallas kernel cannot — at
    epilogue time the VMEM-resident lhs/rhs tile is the last (K-indexed)
    fetch, not an (M, N)-shaped value."""
    con = {o.name for o in graph.operands if o.kind in ("lhs", "rhs")}
    return frozenset(r for nd in graph.nodes for r in nd.inputs if r in con)


def _compile_pallas(graph: TppGraph, *, spec_string=DEFAULT_SPEC, tiles=None,
                    block_steps=None, out_dtype=None, interpret=False,
                    mesh=None, vmem_limit_bytes=None, hw_prng=False,
                    ignore=frozenset()):
    bad = contraction_operand_values(graph)
    if bad:
        raise FusionLegalityError(
            f"graph {graph.name!r}: contraction operand(s) {sorted(bad)} are "
            "referenced as epilogue values — the fused Pallas kernel only "
            "sees their K-indexed tiles at epilogue time; use the XLA path "
            "for this graph", code="TPP207")
    reducing = graph.reducing_node()
    red_idx = graph.nodes.index(reducing) if reducing is not None else None
    pre_nodes = graph.nodes if reducing is None else graph.nodes[:red_idx]
    post_nodes = graph.post_reduce_nodes()
    chain = graph.chained_root()
    base_roots = graph.base_roots
    # a chained graph stages NOTHING: the reduced value streams straight
    # into the chain accumulator under the (running max, running sum) strip
    staged = () if chain is not None else graph.staged_values()
    row_res = graph.row_resident_operands()
    con_specs = graph.contraction_operands
    ep_specs = graph.epilogue_operands
    roots = graph.roots
    outputs = graph.outputs
    # position of each contraction operand in the packed/ref order
    con_pos = {s.name: i for i, s in enumerate(con_specs)}
    con_trans = {s.name: s.trans for s in con_specs}
    red_op = EPILOGUE_OPS[reducing.op] if reducing is not None else None
    # the stats strip accumulates (sum, sum-sq) of the op's declared stats
    # input tile-by-tile — only possible when that input is a staged panel
    # (a computed value); ops without a stats formula run a full-row apply
    use_stats = (
        reducing is not None and red_op.stats_input is not None
        and reducing.op in _STATS_CLOSE
        and reducing.inputs[red_op.stats_input] in staged)
    stats_name = (reducing.inputs[red_op.stats_input] if use_stats else None)
    # the chained recurrence streams the reducer's stats input (the masked
    # score tile): max/sum update + rescale, never a materialized panel
    chain_in = (reducing.inputs[red_op.stats_input]
                if chain is not None else None)
    # counter-PRNG ops key their draw on global element coordinates; the
    # hardware generator (opt-in, real TPU only — interpret mode has no HW
    # PRNG) trades that schedule invariance for throughput
    has_offset_ops = any(EPILOGUE_OPS[nd.op].wants_offsets
                         for nd in graph.nodes)
    use_hw_bits = bool(hw_prng) and not interpret
    # root accumulators consumed by the epilogue DAG: those roots must carry
    # the full N width — only output-only roots may be narrow (their pad
    # columns are never read, just stacked as zeros)
    consumed_roots = {graph.resolve_acc(ref)
                      for nd in graph.nodes for ref in nd.inputs}
    plan_cache: dict = {}  # (operand shapes/dtypes) -> pallas call

    def build_call(m, k, n, x_dtype, odt, rhs_widths, chain_n2):
        # every call here is one planned fused nest for a NEW operand shape —
        # the recompile point the fusion.lowerings counter tracks
        obs_metrics.default_registry().counter("fusion.lowerings").inc()
        with obs_trace.get_tracer().span(
                "fusion.lower", cat="fusion", graph=graph.name,
                m=m, k=k, n=n, spec=spec_string):
            return _build_call(m, k, n, x_dtype, odt, rhs_widths, chain_n2)

    def _build_call(m, k, n, x_dtype, odt, rhs_widths, chain_n2):
        import math

        from repro.kernels.brgemm import pick_tiles
        bm, bk, bn = tiles or pick_tiles(m, k, n, x_dtype)
        if rhs_widths and tiles is None:
            # shrink the N tile so every narrow width is a whole number of
            # tiles (gcd still divides n); explicitly passed tiles are the
            # caller's contract and stay untouched — the check below rejects
            bn = math.gcd(bn, *rhs_widths.values())
        loops, in_maps, out_map = build_nest_inputs(
            graph, m, k, n, (bm, bk, bn), block_steps,
            rhs_widths=rhs_widths, chain_n2=chain_n2)
        tl = ThreadedLoop(loops, spec_string, reduction_letters=("a",))
        validate_reduction_innermost(tl.nest, ("b", "c"), ("a",))
        validate_epilogue_band(tl.nest, graph)
        if has_offset_ops:
            from repro.analysis import footprint
            footprint.enforce(footprint.check_prng_mesh(tl.nest, graph),
                              exc=FusionLegalityError)
        plan = plan_pallas(tl.nest, in_maps, out_map, reduction_letters=("a",))

        kb = k // bk
        nb = n // bn
        k_step = tl.nest.innermost_step("a")
        c_step = tl.nest.innermost_step("c")
        acc_m = tl.nest.innermost_step("b") * bm
        acc_n = c_step * bn
        for nm, w in rhs_widths.items():
            if w % acc_n:
                raise FusionLegalityError(
                    f"graph {graph.name!r}: narrow rhs operand {nm!r} width "
                    f"{w} is not a whole number of N blocks (block {acc_n}) "
                    "— pass tiles/block_steps whose N block divides every "
                    "per-root width", code="TPP108")
        n_con = len(con_specs)
        n_ep = len(ep_specs)
        n_out = len(outputs)
        # width of each base root's accumulator band (n unless its rhs is
        # narrow); accumulation for tiles past the band is skipped, leaving
        # the zero-initialized columns in place — the stacked output is
        # therefore zero-padded, exactly like the XLA path
        root_w = {r.name: rhs_widths.get(r.rhs, n) for r in base_roots}

        def body(ind, *refs):
            con_refs = refs[:n_con]
            ep_refs = {s.name: r
                       for s, r in zip(ep_specs, refs[n_con:n_con + n_ep])}
            o_ref = refs[n_con + n_ep]
            scratch = refs[n_con + n_ep + 1:]
            acc_refs = {r.name: scratch[i] for i, r in enumerate(base_roots)}
            n_acc = len(base_roots)
            if chain is not None:
                chain_ref, cstats_ref = scratch[n_acc], scratch[n_acc + 1]
                panel_refs, stats_ref = {}, None
            else:
                chain_ref = cstats_ref = None
                panel_refs = {nm: scratch[n_acc + i]
                              for i, nm in enumerate(staged)}
                stats_ref = (scratch[n_acc + len(staged)]
                             if use_stats else None)
            ik = ind["a"]
            jc = ind["c"]
            ib = ind["b"]

            def node_kwargs(nd, op, col0):
                """Static attrs + (for PRNG ops) the tile's global element
                offsets: rows start at ib*bm, cols at ``col0`` (the current
                N tile for pre-reduce nodes, 0 for full-row panels)."""
                kw = nd.attr_dict()
                if op.wants_offsets:
                    kw["_offsets"] = (ib * bm, col0)
                    if use_hw_bits:
                        kw["_impl"] = "hw"
                return kw

            if use_stats:
                @pl.when(jnp.logical_and(jc == 0, ik == 0))
                def _():
                    stats_ref[...] = jnp.zeros_like(stats_ref)

            if chain is not None:
                # chain accumulator + (running max, running sum) strip live
                # across EVERY N visit of a row — reset only at row start
                @pl.when(jnp.logical_and(jc == 0, ik == 0))
                def _():
                    chain_ref[...] = tpp.zero(chain_ref.shape, chain_ref.dtype)
                    cstats_ref[:, 0] = jnp.full((acc_m,), _NEG_INF,
                                                jnp.float32)
                    cstats_ref[:, 1] = jnp.zeros((acc_m,), jnp.float32)

            @pl.when(ik == 0)
            def _():
                for acc_ref in acc_refs.values():
                    acc_ref[...] = tpp.zero(acc_ref.shape, acc_ref.dtype)

            # one MXU issue per base root; a shared lhs tile is read from
            # its (single) VMEM ref once per root, fetched from HBM once.  A
            # trans operand's tile arrives in stored (transposed) layout —
            # the dot_general contracts over the matching dim instead of
            # materializing a transpose.  A narrow rhs (per-root N width,
            # GQA) is wholly resident: slice the live N tile out of it and
            # skip tiles past the width — those acc columns stay zero.
            for root in base_roots:
                lc = 0 if con_trans[root.lhs] else 1
                rc = 1 if con_trans[root.rhs] else 0
                a_ref = con_refs[con_pos[root.lhs]]
                b_ref = con_refs[con_pos[root.rhs]]
                if root_w[root.name] == n:
                    acc_refs[root.name][...] += jax.lax.dot_general(
                        a_ref[...], b_ref[...],
                        dimension_numbers=(((lc,), (rc,)), ((), ())),
                        preferred_element_type=jnp.float32,
                    )
                else:
                    def _narrow_acc(root=root, a_ref=a_ref, b_ref=b_ref,
                                    lc=lc, rc=rc):
                        tile = (b_ref[pl.ds(jc * bn, acc_n), :]
                                if con_trans[root.rhs]
                                else b_ref[:, pl.ds(jc * bn, acc_n)])
                        acc_refs[root.name][...] += jax.lax.dot_general(
                            a_ref[...], tile,
                            dimension_numbers=(((lc,), (rc,)), ((), ())),
                            preferred_element_type=jnp.float32,
                        )
                    pl.when(jc * bn + acc_n <= root_w[root.name])(_narrow_acc)

            # last K visit: run the epilogue DAG on the VMEM-resident tiles
            @pl.when(ik == kb - k_step)
            def _():
                env = {r.name: acc_refs[r.name][...] for r in base_roots}
                if len(roots) == 1:
                    env["acc"] = env[roots[0].name]

                def value(ref, full_row=False):
                    if ref in env:
                        return env[ref]
                    spec = graph.operand(ref)
                    r = ep_refs[ref]
                    if spec.kind == "rowvec":
                        v = r[...] if full_row else r[:, pl.ds(jc * bn, acc_n)]
                        return v.astype(jnp.float32)
                    if spec.name in row_res and not full_row:
                        # full-row block; pre-reduce consumers slice their
                        # current N tile out of it
                        v = r[:, pl.ds(jc * bn, acc_n)]
                    else:
                        v = r[...]
                    return (v if spec.kind in ("mask", "scalar")
                            else v.astype(jnp.float32))

                for nd in pre_nodes:
                    op = EPILOGUE_OPS[nd.op]
                    env[nd.name] = op.apply(
                        *(value(r) for r in nd.inputs),
                        **node_kwargs(nd, op, jc * bn))

                if reducing is None:
                    if n_out > 1:
                        o_ref[...] = jnp.stack(
                            [env[o] for o in outputs]).astype(o_ref.dtype)
                    else:
                        o_ref[...] = env[outputs[0]].astype(o_ref.dtype)
                    return

                if chain is not None:
                    # streaming online-softmax recurrence (the statistics
                    # strip generalized from (sum, sum-sq) to (running max,
                    # running sum)): when a new N tile raises a row's max,
                    # both the running sum and the chain accumulator are
                    # rescaled by exp(m_prev - m_new) — so at the final N
                    # visit chain/l IS softmax(z) @ V without the (M, N)
                    # panel ever existing.  Scores at/below _MASK_FLOOR are
                    # masked-out fills: their exp contribution is pinned to
                    # zero (a fully-masked tile must not contribute exp(0)
                    # while the running max is still _NEG_INF).
                    zt = env[chain_in]
                    m_prev = cstats_ref[:, 0:1]
                    l_prev = cstats_ref[:, 1:2]
                    m_new = jnp.maximum(m_prev,
                                        jnp.max(zt, axis=1, keepdims=True))
                    alpha = jnp.exp(m_prev - m_new)
                    p = jnp.where(zt > _MASK_FLOOR,
                                  jnp.exp(zt - m_new), 0.0)
                    cstats_ref[:, 0] = m_new[:, 0]
                    cstats_ref[:, 1] = (l_prev * alpha
                                        + jnp.sum(p, axis=1,
                                                  keepdims=True))[:, 0]
                    v_tile = con_refs[con_pos[chain.rhs]][...].astype(
                        jnp.float32)
                    chain_ref[...] = chain_ref[...] * alpha + \
                        jax.lax.dot_general(
                            p, v_tile,
                            dimension_numbers=(((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

                    @pl.when(jc == nb - c_step)
                    def _():
                        # close: normalize by the running sum.  A fully
                        # masked row has l == 0 → output 0 (the reference
                        # kernels' convention), never a division by zero.
                        l = jnp.maximum(cstats_ref[:, 1:2], 1e-30)
                        o_ref[...] = (chain_ref[...] / l).astype(o_ref.dtype)
                    return

                # row-panel statistics trick (kernels.fused_output,
                # generalized): stage each computed value the reducing op
                # consumes, close the (sum, sum-sq) strip over its stats
                # input, and run the reduction — plus any post-reduce
                # pointwise nodes — on the finished panels at the last N
                # visit
                for nm in staged:
                    panel_refs[nm][:, pl.ds(jc * bn, acc_n)] = env[nm]
                if use_stats:
                    zt = env[stats_name]
                    stats_ref[:, 0] += jnp.sum(zt, axis=1)
                    stats_ref[:, 1] += jnp.sum(zt * zt, axis=1)

                @pl.when(jc == nb - c_step)
                def _():
                    attrs = reducing.attr_dict()
                    fullenv = {nm: panel_refs[nm][...] for nm in staged}

                    def fval(ref):
                        if ref in fullenv:
                            return fullenv[ref]
                        return value(ref, full_row=True)

                    vals = [fval(r)
                            for r in reducing.inputs[:red_op.value_arity]]
                    params = [fval(r)
                              for r in reducing.inputs[red_op.value_arity:]]
                    if use_stats:
                        y = _STATS_CLOSE[reducing.op](
                            vals, params, stats_ref[...], n, attrs)
                    else:  # softmax & any panel-wide reducer: full-row apply
                        y = red_op.apply(*vals, *params, **attrs)
                    fullenv[reducing.name] = y

                    for nd in post_nodes:
                        op = EPILOGUE_OPS[nd.op]
                        fullenv[nd.name] = op.apply(
                            *(fval(r) for r in nd.inputs),
                            **node_kwargs(nd, op, 0))

                    if n_out > 1:
                        o_ref[...] = jnp.stack(
                            [fullenv[o] for o in outputs]).astype(o_ref.dtype)
                    else:
                        o_ref[...] = fullenv[outputs[0]].astype(o_ref.dtype)

        scratch_shapes = [pltpu.VMEM((acc_m, acc_n), jnp.float32)
                          for _ in base_roots]
        if chain is not None:
            scratch_shapes += [
                pltpu.VMEM((acc_m, chain_n2), jnp.float32),   # chain acc
                pltpu.VMEM((acc_m, 2), jnp.float32),  # (run max, run sum)
            ]
        elif reducing is not None:
            scratch_shapes += [pltpu.VMEM((acc_m, n), jnp.float32)
                               for _ in staged]       # staged row panels
            if use_stats:
                scratch_shapes.append(
                    pltpu.VMEM((acc_m, 2), jnp.float32))  # (sum, sum-sq)

        db = jnp.dtype(x_dtype).itemsize
        ep_elems = sum(
            (m * n if s.kind in ("tile", "mask")
             else (1 if s.kind == "scalar" else n)) for s in ep_specs)
        con_elems = sum(
            (m * k if s.kind == "lhs"
             else n * chain_n2 if s.kind == "crhs"
             else k * rhs_widths.get(s.name, n)) for s in con_specs)
        if chain is not None:
            out_shape = (m, chain_n2)
            out_elems = m * chain_n2
        else:
            out_shape = (n_out, m, n) if n_out > 1 else (m, n)
            out_elems = n_out * m * n
        return make_pallas_fn(
            plan,
            body,
            jax.ShapeDtypeStruct(out_shape, odt),
            scratch_shapes=scratch_shapes,
            interpret=interpret,
            mesh=mesh,
            vmem_limit_bytes=vmem_limit_bytes,
            cost_estimate=pl.CostEstimate(
                flops=2 * m * n * k * len(base_roots)
                + (2 * m * n * chain_n2 if chain is not None else 0)
                + int(graph.epilogue_flops_per_elem() * m * n),
                bytes_accessed=(con_elems + ep_elems) * db
                + out_elems * jnp.dtype(odt).itemsize,
                transcendentals=0,
            ),
        )

    def fn(**operands):
        packed = _pack_operands(graph, operands, ignore)
        x = packed[0]   # contraction_operands lead with roots[0].lhs
        if con_specs[0].trans:
            k, m = x.shape
        else:
            m, k = x.shape
        for spec, v in zip(con_specs, packed):
            if spec.kind == "lhs":
                want = (k, m) if spec.trans else (m, k)
                if v.shape != want:
                    raise FusionLegalityError(
                        f"graph {graph.name!r}: lhs operand {spec.name!r} "
                        f"has shape {v.shape}, expected {want} — multi-root "
                        "graphs share one (M, K, N) problem shape")
        # per-root N widths: every rhs must share K; the nest's N is the
        # WIDEST rhs, narrower ones (GQA K/V) become sliced-resident maps
        widths = {}
        for spec, v in zip(con_specs, packed):
            if spec.kind != "rhs":
                continue
            kk, w = ((v.shape[1], v.shape[0]) if spec.trans else v.shape)
            if kk != k:
                raise FusionLegalityError(
                    f"graph {graph.name!r}: rhs operand {spec.name!r} has "
                    f"shape {v.shape}, expected K = {k} on its contraction "
                    "dim — all roots share the (M, K) problem")
            widths[spec.name] = w
        n = max(widths.values())
        rhs_widths = {nm: w for nm, w in widths.items() if w < n}
        if rhs_widths:
            bad = sorted(r.name for r in base_roots
                         if r.rhs in rhs_widths and r.name in consumed_roots)
            if bad:
                raise FusionLegalityError(
                    f"graph {graph.name!r}: rhs widths differ ({widths}) but "
                    f"root(s) {bad} feed epilogue nodes — per-root N widths "
                    "apply only to output-only roots (stacked, zero-padded); "
                    "epilogue-combined roots share one (M, K, N) problem "
                    "shape")
        chain_n2 = None
        if chain is not None:
            cv = packed[con_pos[chain.rhs]]
            if cv.ndim != 2 or cv.shape[0] != n:
                raise FusionLegalityError(
                    f"graph {graph.name!r}: crhs operand {chain.rhs!r} has "
                    f"shape {getattr(cv, 'shape', None)}, expected (N, N2) "
                    f"= ({n}, *) — the chain contracts over the base "
                    "roots' N axis", code="TPP213")
            chain_n2 = cv.shape[1]
        odt = out_dtype or x.dtype
        key = tuple((v.shape, jnp.dtype(v.dtype).name) for v in packed)
        call = plan_cache.get(key)
        if call is None:
            call = build_call(m, k, n, x.dtype, odt, rhs_widths, chain_n2)
            plan_cache[key] = call
        return call(*packed)

    return fn


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def compile(graph: TppGraph, *, path: str = "pallas", simplify: bool = True,
            **kw):
    """Lower ``graph`` to a callable ``fn(**operands) -> (M, N) array``
    (``(R, M, N)`` for an R-output graph).

    The graph is first run through :func:`simplify_graph` (identity / rate-0
    dropout elimination + dead-operand removal); operands the simplification
    dropped remain accepted — and ignored — at call time.  ``path="pallas"``
    (default) emits one fused Pallas kernel; ``path="xla"`` emits the
    composed-TPP reference.  Keyword options for the Pallas path:
    ``spec_string``, ``tiles``, ``block_steps``, ``out_dtype``, ``interpret``,
    ``mesh``, ``vmem_limit_bytes``, ``hw_prng`` (draw ``dropout_rng`` bits
    from the TPU hardware generator — faster on real TPUs but NOT
    schedule-invariant or reference-bit-identical; see ``fusion.rng``); the
    XLA path takes ``out_dtype`` only.
    """
    lowered = simplify_graph(graph) if simplify else graph
    # Two live same-kind PRNG draws sharing a salt would emit identical bits
    # at both sites — a silent correctness bug; refuse to compile (TPP203).
    from repro.fusion import rng
    rng.assert_unique_salts(lowered)
    ignore = frozenset(graph.operand_names) - frozenset(lowered.operand_names)
    if path == "xla":
        allowed = {"out_dtype"}
        bad = set(kw) - allowed
        if bad:
            raise TypeError(f"xla path does not accept {sorted(bad)}")
        return _compile_xla(lowered, ignore=ignore, **kw)
    if path == "pallas":
        return _compile_pallas(lowered, ignore=ignore, **kw)
    raise ValueError(f"unknown lowering path {path!r}; use 'pallas' or 'xla'")


_COMPILE_CACHE: dict = {}

# Graceful degradation: graphs whose fused Pallas lowering failed, now
# permanently routed through the composed-TPP XLA reference (the paper's
# "every primitive has a reference semantic" payoff).  Keyed by the graph
# itself; ``fallback_blocklist()`` exposes a name→reason view.
_FALLBACK_BLOCKLIST: dict = {}
_FORCED_FAILURES: set[str] = set()   # graph names (fault injection / tests)
_LOG = logging.getLogger("repro.fusion")


class ForcedPallasFailure(RuntimeError):
    """Raised in place of running a fused kernel under
    :func:`force_pallas_failure` — exercises the XLA fallback path."""


def _fallback_enabled() -> bool:
    # strict mode (REPRO_FUSION_FALLBACK=0): lowering failures are fatal,
    # as before this layer existed — for CI jobs that must not silently
    # lose fused coverage
    return os.environ.get("REPRO_FUSION_FALLBACK", "1") != "0"


def fallback_blocklist() -> dict[str, str]:
    """{graph name: failure reason} for every graph currently degraded to
    the XLA reference."""
    return {g.name: reason for g, reason in _FALLBACK_BLOCKLIST.items()}


def clear_fallback_blocklist() -> None:
    """Forget recorded lowering failures (e.g. after an env/backend change
    that may have fixed them); blocklisted graphs will retry Pallas on
    their next fresh compile."""
    _FALLBACK_BLOCKLIST.clear()


@contextlib.contextmanager
def force_pallas_failure(*names: str):
    """Fault injection: within the context, calling the fused Pallas
    lowering of the named graphs raises, driving ``compile_for_backend``'s
    XLA fallback.  On exit the forcing — and any blocklist entries it
    caused — are removed, so a chaos test leaves the process clean."""
    _FORCED_FAILURES.update(names)
    try:
        yield
    finally:
        _FORCED_FAILURES.difference_update(names)
        for g in [g for g in _FALLBACK_BLOCKLIST if g.name in names]:
            del _FALLBACK_BLOCKLIST[g]


def _note_fallback(graph: TppGraph, exc: BaseException) -> None:
    if graph not in _FALLBACK_BLOCKLIST:
        reason = f"{type(exc).__name__}: {exc}"
        _FALLBACK_BLOCKLIST[graph] = reason
        obs_metrics.default_registry().counter("fusion.fallbacks").inc()
        obs_trace.get_tracer().event("fusion.fallback", cat="fusion",
                                     graph=graph.name, reason=reason)
        _LOG.warning(
            "fused Pallas lowering of graph %r failed (%s); falling back to "
            "the composed-TPP XLA reference for this graph (set "
            "REPRO_FUSION_FALLBACK=0 to make this fatal)", graph.name, reason)


def _guarded_pallas(graph: TppGraph, backend: str, kw: dict):
    """Compile the fused Pallas path with call-time XLA fallback.  Pallas
    plan/lowering errors surface either at compile() time (epilogue-band
    legality) or at first call per shape (tile divisibility, Mosaic) — both
    are caught, logged once, blocklisted, and rerouted to the XLA
    reference; ``TypeError`` (caller passed wrong operands) stays fatal."""
    xla_kw = {k: v for k, v in kw.items() if k == "out_dtype"}
    state: dict = {"xla_fn": None}

    def xla_fn():
        if state["xla_fn"] is None:
            state["xla_fn"] = compile(graph, path="xla", **xla_kw)
        return state["xla_fn"]

    try:
        pallas_fn = compile(graph, path="pallas",
                            interpret=(backend == "pallas_interpret"), **kw)
    except Exception as exc:
        if not _fallback_enabled():
            raise
        _note_fallback(graph, exc)
        pallas_fn = None

    def guarded(**operands):
        if pallas_fn is None or graph in _FALLBACK_BLOCKLIST:
            return xla_fn()(**operands)
        try:
            if graph.name in _FORCED_FAILURES:
                raise ForcedPallasFailure(
                    f"forced Pallas failure for graph {graph.name!r}")
            return pallas_fn(**operands)
        except TypeError:
            raise               # operand-signature error, not a lowering bug
        except Exception as exc:
            if not _fallback_enabled():
                raise
            _note_fallback(graph, exc)
            return xla_fn()(**operands)

    return guarded


def compile_for_backend(graph: TppGraph, backend: Optional[str] = None, **kw):
    """Pick the lowering path from the active ``kernels.ops`` backend — the
    hook ``models.blocks`` uses behind the ``use_fusion`` config flag.

    Compiled callables are memoized on ``(graph, backend, kwargs)`` — the
    library ``fused_*_apply`` helpers call this per layer invocation, and
    rebuilding the closure (plus re-planning the nest inside it) per eager
    call is pure waste.  The returned callable itself caches one pallas plan
    per distinct operand-shape/dtype tuple.

    Unlike :func:`compile` (which raises on lowering failures — the strict
    path tests and tools use), the pallas-backend callables returned here
    degrade gracefully: a graph whose fused lowering fails is logged once,
    blocklisted, and routed through the composed-TPP XLA reference, so
    ``use_fusion=True`` models survive a backend that cannot compile a
    shape.  ``REPRO_FUSION_FALLBACK=0`` restores strictness."""
    from repro.kernels import ops
    backend = backend or ops.current_backend()
    if backend == "xla":
        kw.pop("tiles", None)
        kw.pop("spec_string", None)
        kw.pop("block_steps", None)
        kw.pop("hw_prng", None)
    reg = obs_metrics.default_registry()
    try:
        key = (graph, backend,
               tuple(sorted((k, _freeze_kw(v)) for k, v in kw.items())))
        hit = _COMPILE_CACHE.get(key)
    except TypeError:   # unhashable kwarg (e.g. a live mesh object)
        key, hit = None, None
    if hit is not None:
        reg.counter("fusion.compile_cache.hits").inc()
        return hit
    reg.counter("fusion.compile_cache.misses").inc()
    with obs_trace.get_tracer().span("fusion.compile", cat="fusion",
                                     graph=graph.name, backend=backend):
        if backend == "xla":
            fn = compile(graph, path="xla", **kw)
        else:
            fn = _guarded_pallas(graph, backend, kw)
    if key is not None:
        _COMPILE_CACHE[key] = fn
    return fn
