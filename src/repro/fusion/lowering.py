"""Lower a ``TppGraph`` three ways (paper Fig. 1):

  * ``path="xla"``    — the reference: compose the ``core.tpp`` functions on
    full arrays and let XLA fuse them (the paper's "straightforward"
    framework path);
  * ``path="pallas"`` — ONE fused Pallas kernel: the contraction runs under a
    PARLOOPER ``loop_spec_string`` (letters ``a``=K reduction, ``b``=M,
    ``c``=N, exactly ``kernels.brgemm``), the epilogue DAG is applied to the
    fp32 accumulator tile while it is VMEM-resident, and normalizing
    epilogues (layernorm / rmsnorm / softmax over N) use the row-panel
    statistics trick of ``kernels.fused_output``: the pre-norm row panel is
    staged in VMEM scratch, (sum, sum-of-squares) statistics accumulate per
    N tile, and the normalization equation is applied to the finished panel
    on the last N visit;
  * the cost path lives in ``fusion.cost`` (perf-model + autotune hook).

Legality: besides the usual K-innermost requirement
(``validate_reduction_innermost``), a normalizing epilogue pins the N loop to
the nest's innermost band *under* every M level — a row's tiles must be
visited consecutively for its statistics to close before the panel is reused.
``validate_epilogue_band`` diagnoses schedules that violate this instead of
producing silently wrong kernels (the paper leaves such legality to the user).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import tpp
from repro.core.loops import LoopSpec, ThreadedLoop
from repro.core.pallas_lowering import (TensorMap, make_pallas_fn, plan_pallas,
                                        validate_reduction_innermost)
from repro.fusion.graph import (EPILOGUE_OPS, FusionLegalityError, TppGraph)

__all__ = [
    "compile", "compile_for_backend", "validate_epilogue_band",
    "build_nest_inputs", "DEFAULT_SPEC",
]

DEFAULT_SPEC = "bca"  # M, N outer; K (reduction) innermost — output-stationary


# ---------------------------------------------------------------------------
# Legality
# ---------------------------------------------------------------------------

def validate_epilogue_band(nest, graph: TppGraph, *, m_letter="b", n_letter="c"):
    """A normalizing epilogue reduces over N; its row panel closes only when
    all N tiles of a row are visited consecutively.  Reject schedules where
    any N level sits outside (above) an M level, where the N loop is
    parallelized (statistics accumulate sequentially), or where N is sharded
    over a mesh axis (the row statistics would be partial per shard)."""
    nd = graph.reducing_node()
    if nd is None:
        return
    grid = [(p, l) for p, l in enumerate(nest.levels) if l.mesh_axis is None]
    m_pos = [p for p, l in grid if l.letter == m_letter]
    n_pos = [p for p, l in grid if l.letter == n_letter]
    if m_pos and n_pos and max(m_pos) > min(n_pos):
        raise FusionLegalityError(
            f"graph {graph.name!r}: epilogue {nd.op!r} reduces over the N "
            f"axis but spec {nest.spec.raw!r} places an N loop level (grid "
            f"position {min(n_pos)}) outside the innermost band (deepest M "
            f"level at {max(m_pos)}) — row statistics would close before the "
            "row is complete. Use an N-inside-M order, e.g. 'bca'.")
    if any(l.parallel for p, l in grid if l.letter == n_letter):
        raise FusionLegalityError(
            f"graph {graph.name!r}: epilogue {nd.op!r} reduces over N; the N "
            f"loop in spec {nest.spec.raw!r} cannot take PARALLEL grid "
            "semantics (row statistics accumulate sequentially).")
    if any(l.letter == n_letter for l in nest.mesh_levels):
        raise FusionLegalityError(
            f"graph {graph.name!r}: epilogue {nd.op!r} reduces over N; "
            f"sharding N over a mesh axis in {nest.spec.raw!r} would leave "
            "per-shard partial row statistics (no cross-shard norm combine).")


# ---------------------------------------------------------------------------
# Shared nest construction (also used by fusion.cost)
# ---------------------------------------------------------------------------

def build_nest_inputs(graph: TppGraph, m: int, k: int, n: int,
                      tiles: tuple[int, int, int],
                      block_steps: Optional[dict] = None):
    """LoopSpecs + TensorMaps for lowering ``graph`` at problem size
    (M, K, N) with base tiles (bm, bk, bn).  Operand order is
    ``[lhs, rhs, *epilogue_operands]`` (graph declaration order); row
    vectors are fully VMEM-resident ``(1, n)`` blocks, (M, N) operands are
    tiled with the output."""
    bm, bk, bn = tiles
    if m % bm or k % bk or n % bn:
        raise FusionLegalityError(
            f"graph {graph.name!r}: problem ({m},{k},{n}) not divisible by "
            f"tiles ({bm},{bk},{bn})")
    mb, kb, nb = m // bm, k // bk, n // bn
    block_steps = block_steps or {}
    loops = [
        LoopSpec(0, kb, 1, block_steps=tuple(block_steps.get("a", ())), name="K"),
        LoopSpec(0, mb, 1, block_steps=tuple(block_steps.get("b", ())), name="M"),
        LoopSpec(0, nb, 1, block_steps=tuple(block_steps.get("c", ())), name="N"),
    ]
    in_maps = [
        TensorMap(("b", "a"), (bm, bk), layout="flat"),
        TensorMap(("a", "c"), (bk, bn), layout="flat"),
    ]
    for spec in graph.epilogue_operands:
        if spec.kind in ("tile", "mask"):
            in_maps.append(TensorMap(("b", "c"), (bm, bn), layout="flat"))
        else:  # rowvec — whole vector visible every call (norms need full N)
            in_maps.append(TensorMap((None, None), (1, n), layout="flat"))
    if graph.reducing_node() is not None:
        out_map = TensorMap(("b", None), (bm, n), layout="flat")
    else:
        out_map = TensorMap(("b", "c"), (bm, bn), layout="flat")
    return loops, in_maps, out_map


def _pack_operands(graph: TppGraph, operands: dict):
    """Canonically order ([lhs, rhs, *epilogue-operands]) and reshape
    call-time operands: rowvecs (n,) → (1, n).  Canonical order is
    independent of the graph's declaration order — the Pallas lowering's
    TensorMaps are built in the same order."""
    packed = []
    for spec in (graph.lhs, graph.rhs) + graph.epilogue_operands:
        if spec.name not in operands:
            raise TypeError(
                f"graph {graph.name!r}: missing operand {spec.name!r}; "
                f"expected {graph.operand_names}")
        v = operands[spec.name]
        if spec.kind == "rowvec":
            v = v.reshape(1, -1)
        packed.append(v)
    extra = set(operands) - set(graph.operand_names)
    if extra:
        raise TypeError(f"graph {graph.name!r}: unexpected operands {sorted(extra)}")
    return packed


# ---------------------------------------------------------------------------
# Path 1: XLA reference — compose core.tpp functions, let XLA fuse
# ---------------------------------------------------------------------------

def _compile_xla(graph: TppGraph, *, out_dtype=None):
    def fn(**operands):
        _pack_operands(graph, operands)  # validates the operand set
        x, w = operands[graph.lhs.name], operands[graph.rhs.name]
        acc = tpp.gemm(x, w, beta=0.0, out_dtype=jnp.float32)
        env = {"acc": acc}

        def value(ref):
            if ref in env:
                return env[ref]
            spec = graph.operand(ref)
            v = operands[ref]
            return v if spec.kind == "mask" else v.astype(jnp.float32)

        for nd in graph.nodes:
            op = EPILOGUE_OPS[nd.op]
            env[nd.name] = op.apply(*(value(r) for r in nd.inputs),
                                    **nd.attr_dict())
        out = env[graph.nodes[-1].name] if graph.nodes else acc
        return out.astype(out_dtype or x.dtype)

    return fn


# ---------------------------------------------------------------------------
# Path 2: one fused Pallas kernel
# ---------------------------------------------------------------------------

def _compile_pallas(graph: TppGraph, *, spec_string=DEFAULT_SPEC, tiles=None,
                    block_steps=None, out_dtype=None, interpret=False,
                    mesh=None, vmem_limit_bytes=None):
    reducing = graph.reducing_node()
    pre_nodes = tuple(nd for nd in graph.nodes if nd is not reducing)
    ep_specs = graph.epilogue_operands

    def fn(**operands):
        packed = _pack_operands(graph, operands)
        x, w = packed[0], packed[1]
        m, k = x.shape
        k2, n = w.shape
        assert k == k2, (x.shape, w.shape)
        odt = out_dtype or x.dtype
        from repro.kernels.brgemm import pick_tiles
        bm, bk, bn = tiles or pick_tiles(m, k, n, x.dtype)
        loops, in_maps, out_map = build_nest_inputs(
            graph, m, k, n, (bm, bk, bn), block_steps)
        tl = ThreadedLoop(loops, spec_string, reduction_letters=("a",))
        validate_reduction_innermost(tl.nest, ("b", "c"), ("a",))
        validate_epilogue_band(tl.nest, graph)
        plan = plan_pallas(tl.nest, in_maps, out_map, reduction_letters=("a",))

        kb = k // bk
        nb = n // bn
        k_step = tl.nest.innermost_step("a")
        c_step = tl.nest.innermost_step("c")
        acc_m = tl.nest.innermost_step("b") * bm
        acc_n = c_step * bn
        n_ep = len(ep_specs)

        def body(ind, *refs):
            a_ref, b_ref = refs[0], refs[1]
            ep_refs = {s.name: r for s, r in zip(ep_specs, refs[2:2 + n_ep])}
            o_ref = refs[2 + n_ep]
            scratch = refs[3 + n_ep:]
            acc_ref = scratch[0]
            ik = ind["a"]
            jc = ind["c"]

            # only the strip-statistics norms consume the stats scratch;
            # softmax-style reducers work off the staged panel alone
            use_stats = reducing is not None and reducing.op in (
                "layernorm", "rmsnorm")
            if reducing is not None:
                panel_ref, stats_ref = scratch[1], scratch[2]

            if use_stats:
                @pl.when(jnp.logical_and(jc == 0, ik == 0))
                def _():
                    stats_ref[...] = jnp.zeros_like(stats_ref)

            @pl.when(ik == 0)
            def _():
                acc_ref[...] = tpp.zero(acc_ref.shape, acc_ref.dtype)

            acc_ref[...] += jax.lax.dot_general(
                a_ref[...], b_ref[...],
                dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )

            # last K visit: run the epilogue DAG on the VMEM-resident tile
            @pl.when(ik == kb - k_step)
            def _():
                env = {"acc": acc_ref[...]}

                def value(ref, full_row=False):
                    if ref in env:
                        return env[ref]
                    spec = graph.operand(ref)
                    r = ep_refs[ref]
                    if spec.kind == "rowvec":
                        v = r[...] if full_row else r[:, pl.ds(jc * bn, acc_n)]
                        return v.astype(jnp.float32)
                    v = r[...]
                    return v if spec.kind == "mask" else v.astype(jnp.float32)

                for nd in pre_nodes:
                    op = EPILOGUE_OPS[nd.op]
                    env[nd.name] = op.apply(
                        *(value(r) for r in nd.inputs), **nd.attr_dict())
                tail = env[pre_nodes[-1].name] if pre_nodes else env["acc"]

                if reducing is None:
                    o_ref[...] = tail.astype(o_ref.dtype)
                    return

                # row-panel statistics trick: stage the pre-norm tile, close
                # the (sum, sum-sq) strip, normalize the panel on the last
                # N visit (kernels.fused_output, generalized)
                panel_ref[:, pl.ds(jc * bn, acc_n)] = tail
                if use_stats:
                    stats_ref[:, 0] += jnp.sum(tail, axis=1)
                    stats_ref[:, 1] += jnp.sum(tail * tail, axis=1)

                @pl.when(jc == nb - c_step)
                def _():
                    attrs = reducing.attr_dict()
                    op = EPILOGUE_OPS[reducing.op]
                    panel = panel_ref[...]
                    params = [value(r, full_row=True)
                              for r in reducing.inputs[op.value_arity:]]
                    if reducing.op == "layernorm":
                        mu = stats_ref[:, 0:1] / n
                        var = jnp.maximum(
                            stats_ref[:, 1:2] / n - mu * mu, 0.0)
                        y = (panel - mu) * jax.lax.rsqrt(
                            var + attrs.get("eps", 1e-5))
                        y = y * params[0] + params[1]
                    elif reducing.op == "rmsnorm":
                        ms = stats_ref[:, 1:2] / n
                        y = panel * jax.lax.rsqrt(
                            ms + attrs.get("eps", 1e-6)) * params[0]
                    else:  # softmax & any panel-wide reducer: full-row apply
                        y = op.apply(panel, *params, **attrs)
                    o_ref[...] = y.astype(o_ref.dtype)

        scratch_shapes = [pltpu.VMEM((acc_m, acc_n), jnp.float32)]
        if reducing is not None:
            scratch_shapes += [
                pltpu.VMEM((acc_m, n), jnp.float32),   # pre-norm row panel
                pltpu.VMEM((acc_m, 2), jnp.float32),   # (sum, sum-sq) strip
            ]

        db = jnp.dtype(x.dtype).itemsize
        ep_elems = sum(
            (m * n if s.kind in ("tile", "mask") else n) for s in ep_specs)
        call = make_pallas_fn(
            plan,
            body,
            jax.ShapeDtypeStruct((m, n), odt),
            scratch_shapes=scratch_shapes,
            interpret=interpret,
            mesh=mesh,
            vmem_limit_bytes=vmem_limit_bytes,
            cost_estimate=pl.CostEstimate(
                flops=2 * m * n * k + int(
                    graph.epilogue_flops_per_elem() * m * n),
                bytes_accessed=(m * k + k * n + ep_elems) * db
                + m * n * jnp.dtype(odt).itemsize,
                transcendentals=0,
            ),
        )
        return call(*packed)

    return fn


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def compile(graph: TppGraph, *, path: str = "pallas", **kw):
    """Lower ``graph`` to a callable ``fn(**operands) -> (M, N) array``.

    ``path="pallas"`` (default) emits one fused Pallas kernel; ``path="xla"``
    emits the composed-TPP reference.  Keyword options for the Pallas path:
    ``spec_string``, ``tiles``, ``block_steps``, ``out_dtype``, ``interpret``,
    ``mesh``, ``vmem_limit_bytes``; the XLA path takes ``out_dtype`` only.
    """
    if path == "xla":
        allowed = {"out_dtype"}
        bad = set(kw) - allowed
        if bad:
            raise TypeError(f"xla path does not accept {sorted(bad)}")
        return _compile_xla(graph, **kw)
    if path == "pallas":
        return _compile_pallas(graph, **kw)
    raise ValueError(f"unknown lowering path {path!r}; use 'pallas' or 'xla'")


def compile_for_backend(graph: TppGraph, backend: Optional[str] = None, **kw):
    """Pick the lowering path from the active ``kernels.ops`` backend — the
    hook ``models.blocks`` uses behind the ``use_fusion`` config flag."""
    from repro.kernels import ops
    backend = backend or ops.current_backend()
    if backend == "xla":
        kw.pop("tiles", None)
        kw.pop("spec_string", None)
        kw.pop("block_steps", None)
        return compile(graph, path="xla", **kw)
    return compile(graph, path="pallas",
                   interpret=(backend == "pallas_interpret"), **kw)
